"""Fault-tolerance units: PreemptionGuard handler lifecycle and the
StragglerDetector's EMA/strike logic (direct tests — previously these were
only exercised indirectly through the train driver)."""

import signal

import pytest

from repro.ft import PreemptionGuard, StragglerDetector


# ---------------------------------------------------------------------------
# PreemptionGuard
# ---------------------------------------------------------------------------

def test_guard_install_uninstall_restores_handlers_exactly():
    before = {s: signal.getsignal(s) for s in (signal.SIGTERM, signal.SIGINT)}
    g = PreemptionGuard()
    assert g.installed
    for s in before:
        assert signal.getsignal(s) == g._handler
    g.uninstall()
    assert not g.installed
    for s, h in before.items():
        assert signal.getsignal(s) == h


def test_guard_uninstall_is_idempotent_and_reinstallable():
    before = {s: signal.getsignal(s) for s in (signal.SIGTERM, signal.SIGINT)}
    g = PreemptionGuard()
    g.uninstall()
    g.uninstall()                         # second call: no-op, no error
    for s, h in before.items():
        assert signal.getsignal(s) == h
    g.install()                           # the same guard can come back
    assert g.installed
    g.uninstall()
    for s, h in before.items():
        assert signal.getsignal(s) == h


def test_guard_double_install_rejected():
    g = PreemptionGuard()
    try:
        with pytest.raises(ValueError):
            g.install()
    finally:
        g.uninstall()


def test_nested_guards_lifo_restore():
    before = signal.getsignal(signal.SIGTERM)
    outer = PreemptionGuard()
    inner = PreemptionGuard()
    assert signal.getsignal(signal.SIGTERM) == inner._handler
    inner.uninstall()
    # inner saved outer's handler, so LIFO uninstall restores it exactly
    assert signal.getsignal(signal.SIGTERM) == outer._handler
    outer.uninstall()
    assert signal.getsignal(signal.SIGTERM) == before


def test_guard_context_manager_and_trigger():
    before = signal.getsignal(signal.SIGINT)
    with PreemptionGuard() as g:
        assert not g.requested
        g.trigger()                       # in-process preemption drill
        assert g.requested
    assert not g.installed
    assert signal.getsignal(signal.SIGINT) == before


def test_guard_handler_sets_requested_without_raising():
    g = PreemptionGuard(install=False)
    assert not g.installed
    g._handler(signal.SIGTERM, None)
    assert g.requested
    g.uninstall()                         # never installed: still a no-op


# ---------------------------------------------------------------------------
# StragglerDetector
# ---------------------------------------------------------------------------

def test_straggler_warmup_first_observation_is_baseline():
    d = StragglerDetector()
    assert d.observe(1.0) is False        # first sample seeds the EMA
    assert d.mean == 1.0
    assert not d.flagged


def test_straggler_flags_after_patience_consecutive_outliers():
    d = StragglerDetector(z=3.0, patience=3)
    for _ in range(10):
        d.observe(1.0)
    assert not d.flagged
    assert d.observe(10.0) is True        # strike 1
    assert not d.flagged
    assert d.observe(10.0) is True        # strike 2
    assert not d.flagged
    assert d.observe(10.0) is True        # strike 3 -> flagged
    assert d.flagged


def test_straggler_recovery_resets_patience():
    d = StragglerDetector(z=3.0, patience=3)
    for _ in range(10):
        d.observe(1.0)
    d.observe(10.0)
    d.observe(10.0)                       # two strikes ...
    assert d.observe(1.0) is False        # ... recovery resets the count
    d.observe(10.0)
    d.observe(10.0)
    assert not d.flagged                  # two fresh strikes still < patience
    d.observe(10.0)
    assert d.flagged


def test_straggler_outliers_do_not_poison_the_baseline():
    d = StragglerDetector(z=3.0, patience=100)
    for _ in range(20):
        d.observe(1.0)
    mean_before = d.mean
    d.observe(50.0)                       # outlier: excluded from the EMA
    assert d.mean == mean_before
    assert d.observe(1.0) is False        # healthy steps still healthy


# ---------------------------------------------------------------------------
# ElasticMesh: device loss -> shrink / remesh / reshard
# ---------------------------------------------------------------------------

def test_shrink_preserves_model_axis():
    from repro.ft import ElasticMesh
    assert ElasticMesh.shrink(8, 2) == (4, 2)
    assert ElasticMesh.shrink(6, 2) == (3, 2)   # data axis absorbs the loss
    assert ElasticMesh.shrink(7, 2) == (3, 2)   # odd survivor count rounds
    with pytest.raises(ValueError):
        ElasticMesh.shrink(1, 2)                # cannot keep the shards


_DEVICE_LOSS = r"""
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.ft import ElasticMesh

devs = jax.devices()
assert len(devs) == 8, len(devs)
em = ElasticMesh()
mesh = em.remesh(devs, model_parallel=2)
assert mesh.devices.shape == (4, 2), mesh.devices.shape

specs = {"w": P("data", "model"), "b": P("model")}
tree = {"w": jnp.arange(12 * 8, dtype=jnp.float32).reshape(12, 8),
        "b": jnp.arange(8, dtype=jnp.float32)}
shd = {k: NamedSharding(mesh, s) for k, s in specs.items()}
tree = ElasticMesh.reshard(tree, shd)

# two devices die; the model axis (parameter shards) must survive intact
survivors = devs[:6]
assert ElasticMesh.shrink(len(survivors), 2) == (3, 2)
mesh2 = em.remesh(survivors, model_parallel=2)
assert mesh2.devices.shape == (3, 2), mesh2.devices.shape
shd2 = {k: NamedSharding(mesh2, s) for k, s in specs.items()}
tree2 = ElasticMesh.reshard(tree, shd2)

for k, v in tree2.items():
    used = {d for d in v.sharding.device_set}
    assert used <= set(survivors), (k, used)

step = jax.jit(lambda t: jax.tree.map(lambda x: x * 2, t),
               out_shardings=shd2)
out = step(tree2)
assert np.array_equal(np.asarray(out["w"]),
                      np.arange(12 * 8, dtype=np.float32).reshape(12, 8) * 2)
assert np.array_equal(np.asarray(out["b"]),
                      np.arange(8, dtype=np.float32) * 2)
print("SUBPROCESS_OK")
"""


def test_elastic_mesh_survives_device_loss(tmp_path):
    """Lose 2 of 8 devices: shrink keeps the model axis, remesh rebuilds
    over the survivors, reshard moves state, and a jitted step runs."""
    import subprocess
    import sys
    from pathlib import Path
    env = {"PYTHONPATH": str(Path(__file__).resolve().parent.parent / "src"),
           "PATH": "/usr/bin:/bin", "HOME": str(tmp_path),
           "JAX_PLATFORMS": "cpu",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    r = subprocess.run([sys.executable, "-c", _DEVICE_LOSS],
                       capture_output=True, text=True, timeout=300, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SUBPROCESS_OK" in r.stdout
