"""Engine behavior: determinism, error propagation, app verification.

The hypothesis-driven property tests (KPN determinism, feedback rings,
scalar/burst equivalence) live in ``test_properties.py`` so this module
collects and runs on a bare environment without ``hypothesis``.
"""

import pytest

import repro
from repro.apps import APPS, FEEDBACK_APPS


def test_coroutine_schedule_deterministic():
    """Same program -> identical switch count and identical channel stats
    on repeated runs (FIFO ready queue, run-to-block)."""
    stats = []
    for _ in range(3):
        r = APPS["cannon"].run(engine="coroutine", P=3, n=4)
        stats.append((r.report.switches, tuple(r.report.channels)))
    assert stats[0] == stats[1] == stats[2]


def test_task_error_propagates():
    def Bad(o):
        o.write(1)
        raise ValueError("boom")

    def C(i, sink):
        for v in i:
            sink.append(v)

    def Top(sink):
        ch = repro.channel()
        repro.task().invoke(Bad, ch).invoke(C, ch, sink)

    for eng in ("coroutine", "thread"):
        rep = repro.run(Top, [], engine=eng)
        assert not rep.ok and "boom" in rep.error


@pytest.mark.parametrize("app", sorted(APPS))
def test_apps_verified_coroutine(app):
    r = APPS[app].run(engine="coroutine")
    assert r.report.ok, r.report.error
    assert r.correct, (app, r.max_err)


@pytest.mark.parametrize("app", sorted(FEEDBACK_APPS))
def test_feedback_apps_fail_sequential(app):
    r = APPS[app].run(engine="sequential")
    assert not r.report.ok


def test_invoke_one_call():
    def P(o: repro.OStream, n):
        for i in range(n):
            o.write(i)
        o.close()

    def C(i: repro.IStream, sink):
        for v in i:
            sink.append(v)

    def Top(sink):
        ch = repro.channel()
        repro.task().invoke(P, ch, 4).invoke(C, ch, sink)
        return sink

    out = repro.invoke(Top, [], target="sim")
    assert out == [0, 1, 2, 3]


@pytest.mark.parametrize("app", ["cannon", "gemm", "network", "page_rank"])
def test_apps_graph_validates(app):
    """Every app's task graph obeys the one-producer/one-consumer/
    same-parent rule (paper Section 3.1.1) under metadata extraction."""
    from repro.core.graph import extract_graph
    from repro.core.engines import CoroutineEngine

    mod = APPS[app]
    top, args, _ = mod.build()
    eng = CoroutineEngine()
    rep = eng.run(top, *args)
    assert rep.ok
    g = extract_graph(eng, rep)
    g.validate()                       # raises on any wiring violation
    assert g.n_instances >= g.n_tasks
    assert g.dedup_factor() >= 1.0
