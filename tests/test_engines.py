"""Engine equivalence + determinism properties (hypothesis).

The KPN-determinism property (paper Section 2.2): for programs whose tasks
read from statically-known channels (no select/try polling), every engine
that completes must produce the *identical* token streams — the schedule
may differ, the data may not.
"""

import pytest
from hypothesis import given, settings, strategies as st

import repro
from repro.apps import APPS, FEEDBACK_APPS


# ---------------------------------------------------------------------------
# generated pipeline programs: Source -> N x Transform -> Sink
# ---------------------------------------------------------------------------

def build_pipeline(values, n_stages, capacity):
    def Source(o):
        for v in values:
            o.write(v)
        o.close()

    def Transform(i, o, mul, add):
        for v in i:
            o.write(v * mul + add)
        o.close()

    def Sink(i, out):
        for v in i:
            out.append(v)

    def Top(out):
        chans = [repro.channel(capacity=capacity) for _ in range(n_stages + 1)]
        t = repro.task().invoke(Source, chans[0])
        for s in range(n_stages):
            t = t.invoke(Transform, chans[s], chans[s + 1], s + 1, s)
        t.invoke(Sink, chans[n_stages], out)

    def expect():
        cur = list(values)
        for s in range(n_stages):
            cur = [v * (s + 1) + s for v in cur]
        return cur

    return Top, expect


@given(values=st.lists(st.integers(-100, 100), max_size=20),
       n_stages=st.integers(1, 4),
       capacity=st.integers(1, 5))
@settings(max_examples=25, deadline=None)
def test_kpn_determinism_across_engines(values, n_stages, capacity):
    results = {}
    for eng in ("coroutine", "thread", "sequential"):
        top, expect = build_pipeline(values, n_stages, capacity)
        out = []
        rep = repro.run(top, out, engine=eng)
        assert rep.ok, (eng, rep.error)
        results[eng] = out
        assert out == expect(), eng
    assert results["coroutine"] == results["thread"] == results["sequential"]


@given(values=st.lists(st.integers(-10, 10), min_size=1, max_size=10),
       capacity=st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_feedback_ring_only_parallel_engines(values, capacity):
    """A 2-task token ring (feedback): coroutine/thread simulate it,
    sequential must fail — the paper's central simulation claim."""
    def A(i, o, sink):
        o.write(values[0])                     # seed the ring
        for _ in range(len(values) - 1):
            v = i.read()
            sink.append(v)
            o.write(v + 1)
        sink.append(i.read())

    def Top(sink):
        c1 = repro.channel(capacity=capacity)
        c2 = repro.channel(capacity=capacity)

        def B(i, o):
            for _ in range(len(values)):
                o.write(i.read())

        repro.task().invoke(A, c2, c1, sink).invoke(B, c1, c2)

    for eng in ("coroutine", "thread"):
        sink = []
        rep = repro.run(Top, sink, engine=eng)
        assert rep.ok, (eng, rep.error)
        assert sink == [values[0] + k for k in range(len(values))]

    rep = repro.run(Top, [], engine="sequential")
    assert not rep.ok


def test_coroutine_schedule_deterministic():
    """Same program -> identical switch count and identical channel stats
    on repeated runs (FIFO ready queue, run-to-block)."""
    stats = []
    for _ in range(3):
        r = APPS["cannon"].run(engine="coroutine", P=3, n=4)
        stats.append((r.report.switches, tuple(r.report.channels)))
    assert stats[0] == stats[1] == stats[2]


def test_task_error_propagates():
    def Bad(o):
        o.write(1)
        raise ValueError("boom")

    def C(i, sink):
        for v in i:
            sink.append(v)

    def Top(sink):
        ch = repro.channel()
        repro.task().invoke(Bad, ch).invoke(C, ch, sink)

    for eng in ("coroutine", "thread"):
        rep = repro.run(Top, [], engine=eng)
        assert not rep.ok and "boom" in rep.error


@pytest.mark.parametrize("app", sorted(APPS))
def test_apps_verified_coroutine(app):
    r = APPS[app].run(engine="coroutine")
    assert r.report.ok, r.report.error
    assert r.correct, (app, r.max_err)


@pytest.mark.parametrize("app", sorted(FEEDBACK_APPS))
def test_feedback_apps_fail_sequential(app):
    r = APPS[app].run(engine="sequential")
    assert not r.report.ok


def test_invoke_one_call():
    def P(o: repro.OStream, n):
        for i in range(n):
            o.write(i)
        o.close()

    def C(i: repro.IStream, sink):
        for v in i:
            sink.append(v)

    def Top(sink):
        ch = repro.channel()
        repro.task().invoke(P, ch, 4).invoke(C, ch, sink)
        return sink

    out = repro.invoke(Top, [], target="sim")
    assert out == [0, 1, 2, 3]


@pytest.mark.parametrize("app", ["cannon", "gemm", "network", "page_rank"])
def test_apps_graph_validates(app):
    """Every app's task graph obeys the one-producer/one-consumer/
    same-parent rule (paper Section 3.1.1) under metadata extraction."""
    from repro.core.graph import extract_graph
    from repro.core.engines import CoroutineEngine

    mod = APPS[app]
    top, args, _ = mod.build()
    eng = CoroutineEngine()
    rep = eng.run(top, *args)
    assert rep.ok
    g = extract_graph(eng, rep)
    g.validate()                       # raises on any wiring violation
    assert g.n_instances >= g.n_tasks
    assert g.dedup_factor() >= 1.0
