"""Launcher coverage: dry-run machinery on a small fake mesh (subprocess),
collective-bytes HLO parser, config registry, train driver smoke."""

import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch.dryrun import collective_bytes

pytestmark = pytest.mark.slow  # JAX-compile-heavy: excluded from the tier-1 default run

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={...}
  %ar.1 = f32[256]{0} all-reduce(%y), to_apply=%sum
  %p = bf16[4,64]{1,0} collective-permute(%z)
  %a2a.s = (f32[16]{0}, f32[16]{0}) all-to-all-start(%w)
  %a2a.d = f32[16]{0} all-to-all-done(%a2a.s)
  %not_a_collective = f32[999]{0} add(%a, %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 256 * 4 * 2          # 2x for ring AR
    assert out["collective-permute"] == 4 * 64 * 2
    assert out["all-to-all"] == 2 * 16 * 4           # -start counted once
    assert out["total_bytes"] == sum(
        v for k, v in out.items()
        if k in ("all-gather", "all-reduce", "collective-permute",
                 "all-to-all"))


def test_config_registry_all_archs():
    assert len(ARCH_IDS) == 10
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.param_count() > 0
        assert cfg.vocab > 0 and cfg.d_model > 0
    # aliases resolve
    assert get_config("qwen3-0.6b").name == "qwen3-0.6b"


def test_exact_published_dims():
    """Spot-check the assignment's published dimensions."""
    c = get_config("yi-6b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.d_ff, c.vocab) == (32, 4096, 32, 4, 11008, 64000)
    c = get_config("grok-1-314b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.vocab, c.moe.n_experts, c.moe.top_k) == \
        (64, 6144, 48, 8, 131072, 8, 2)
    c = get_config("mamba2-130m")
    assert (c.n_layers, c.d_model, c.ssm.d_state) == (24, 768, 128)
    # grok is ~314B total params, ~80B active
    g = get_config("grok-1-314b")
    assert 2.5e11 < g.param_count() < 3.7e11
    assert g.active_param_count() < 1.2e11


def test_shape_applicability_rules():
    long = SHAPES["long_500k"]
    ok, _ = shape_applicable(get_config("mamba2-130m"), long)
    assert ok
    ok, why = shape_applicable(get_config("yi-6b"), long)
    assert not ok and "sub-quadratic" in why
    ok, _ = shape_applicable(get_config("zamba2-1.2b"), long)
    assert ok


def test_dryrun_cell_small_mesh():
    """Lower+compile a reduced arch on a fake 8-device mesh — the dry-run
    machinery end to end, without the 512-device cost."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.configs import get_config
        from repro.launch.dryrun import run_cell
        from repro.models.config import InputShape

        cfg = get_config("qwen3-0.6b").with_reduced()
        shape = InputShape("tiny_train", 128, 8, "train")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rec = run_cell(cfg, shape, mesh, "test4x2", verbose=False)
        assert rec["ok"]
        assert rec["cost"]["flops"] > 0
        assert rec["collectives"]["total_bytes"] > 0
        shape = InputShape("tiny_dec", 128, 8, "decode")
        rec = run_cell(cfg, shape, mesh, "test4x2", verbose=False)
        assert rec["ok"]
        print("SUBPROCESS_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SUBPROCESS_OK" in r.stdout


def test_train_driver_smoke(tmp_path):
    """The end-to-end driver: a few steps, checkpoint, resume."""
    from repro.launch.train import train
    rc = train(["--arch", "qwen3-0.6b", "--reduced", "--steps", "6",
                "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
                "--ckpt-every", "3", "--log-every", "100"])
    assert rc in (0, 1)                   # 1 = loss-did-not-decrease warning
    from repro.ckpt import CheckpointManager
    assert CheckpointManager(tmp_path).latest_step() == 6
    # resume runs zero new steps cleanly
    rc = train(["--arch", "qwen3-0.6b", "--reduced", "--steps", "6",
                "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path)])
    assert rc in (0, 1)
