"""Channel semantics: the full Table-2 API, EoT protocol, graph rules."""

import pytest

import repro
from repro.core.errors import (ChannelMisuse, Deadlock, EndOfTransaction,
                               GraphValidationError)


def run_pair(producer, consumer, capacity=2, engine="coroutine"):
    out = []

    def Top(sink):
        ch = repro.channel(capacity=capacity)
        repro.task().invoke(producer, ch).invoke(consumer, ch, sink)

    rep = repro.run(Top, out, engine=engine)
    return rep, out


class TestBasics:
    def test_fifo_order(self):
        def P(o):
            for i in range(10):
                o.write(i)
            o.close()

        def C(i, sink):
            for v in i:
                sink.append(v)

        rep, out = run_pair(P, C)
        assert rep.ok and out == list(range(10))

    def test_capacity_respected_in_sim(self):
        seen = []

        def P(o):
            for i in range(8):
                o.write(i)
                seen.append(o.channel.size())
            o.close()

        def C(i, sink):
            for v in i:
                sink.append(v)

        rep, out = run_pair(P, C, capacity=3)
        assert rep.ok and max(seen) <= 3

    def test_peek_does_not_consume(self):
        def P(o):
            o.write(42)
            o.close()

        def C(i, sink):
            sink.append(i.peek())
            sink.append(i.peek())
            sink.append(i.read())
            i.open()

        rep, out = run_pair(P, C)
        assert rep.ok and out == [42, 42, 42]

    def test_try_ops_when_empty(self):
        def P(o):
            o.close()

        def C(i, sink):
            ok, v = i.try_read()
            sink.append((ok, v))
            ok, v = i.try_peek()
            sink.append((ok, v))
            i.open()

        rep, out = run_pair(P, C)
        assert rep.ok and out == [(False, None), (False, None)]

    def test_eot_read_raises(self):
        def P(o):
            o.close()

        def C(i, sink):
            with pytest.raises(EndOfTransaction):
                i.read()
            i.open()

        rep, _ = run_pair(P, C)
        assert rep.ok

    def test_multiple_transactions(self):
        def P(o):
            for t in range(3):
                for i in range(t + 1):
                    o.write((t, i))
                o.close()

        def C(i, sink):
            for t in range(3):
                sink.append([v for v in i])

        rep, out = run_pair(P, C)
        assert rep.ok
        assert out == [[(0, 0)], [(1, 0), (1, 1)], [(2, 0), (2, 1), (2, 2)]]


class TestGraphRules:
    def test_two_producers_rejected(self):
        def W(o: repro.OStream):
            o.write(1)

        def R(i: repro.IStream, sink):
            sink.append(i.read())

        def Top(sink):
            ch = repro.channel()
            repro.task().invoke(W, ch).invoke(W, ch).invoke(R, ch, sink)

        rep = repro.run(Top, [], engine="coroutine")
        assert not rep.ok and "producer" in rep.error

    def test_same_task_both_sides_rejected(self):
        def Loop(ch, sink):
            ch.write(1)
            sink.append(ch.read())

        def Top(sink):
            ch = repro.channel()
            repro.task().invoke(Loop, ch, sink)

        rep = repro.run(Top, [], engine="coroutine")
        assert not rep.ok

    def test_elaborate_extracts_metadata(self):
        def P(o: repro.OStream, n):
            for i in range(n):
                o.write(i)
            o.close()

        def C(i: repro.IStream, sink):
            for v in i:
                sink.append(v)

        def Top(sink):
            t = repro.task()
            for _ in range(3):
                ch = repro.channel(capacity=4)
                t = t.invoke(P, ch, 5).invoke(C, ch, sink)

        g = repro.elaborate(Top, [])
        assert g.n_tasks == 3            # Top, P, C definitions
        assert g.n_instances == 7        # 1 + 3 + 3
        assert g.n_channels == 3
        assert g.dedup_factor() == pytest.approx(7 / 3)
        dot = g.to_dot()
        assert "digraph" in dot and "->" in dot


class TestDeadlockDetection:
    def test_simple_deadlock_detected(self):
        def A(i: repro.IStream, o: repro.OStream):
            v = i.read()                 # waits forever
            o.write(v)

        def B(i: repro.IStream, o: repro.OStream):
            v = i.read()
            o.write(v)

        def Top():
            c1 = repro.channel()
            c2 = repro.channel()
            repro.task().invoke(A, c1, c2).invoke(B, c2, c1)

        for eng in ("coroutine", "thread"):
            rep = repro.run(Top, engine=eng)
            assert not rep.ok, eng
            assert "deadlock" in rep.error.lower() or "blocked" in rep.error

    def test_starved_consumer_detected(self):
        def P(o):
            o.write(1)                   # never closes

        def C(i, sink):
            sink.append(i.read())
            sink.append(i.read())        # second read starves

        rep, out = run_pair(P, C)
        assert not rep.ok and out == [1]


class TestSelect:
    def test_select_returns_on_any(self):
        def P1(o: repro.OStream):
            o.write("a")
            o.close()

        def P2(o: repro.OStream):
            o.write("b")
            o.close()

        def C(i1: repro.IStream, i2: repro.IStream, sink):
            done = [False, False]
            ins = [i1, i2]
            while not all(done):
                moved = False
                for s in (0, 1):
                    if done[s]:
                        continue
                    ok, eot = ins[s].try_eot()
                    if ok and eot:
                        ins[s].open()
                        done[s] = True
                        moved = True
                        continue
                    ok, v = ins[s].try_read()
                    if ok:
                        sink.append(v)
                        moved = True
                if not moved and not all(done):
                    repro.select(*(ins[s] for s in (0, 1) if not done[s]))

        def Top(sink):
            c1 = repro.channel()
            c2 = repro.channel()
            repro.task().invoke(P1, c1).invoke(P2, c2).invoke(C, c1, c2, sink)

        for eng in ("coroutine", "thread"):
            rep = repro.run(Top, [], engine=eng)
            assert rep.ok

    def test_detached_task_torn_down(self):
        def Server(i: repro.IStream, o: repro.OStream):
            while True:                  # infinite server
                o.write(i.read() * 2)

        def Client(o: repro.OStream, i: repro.IStream, sink):
            for x in range(5):
                o.write(x)
                sink.append(i.read())

        def Top(sink):
            req = repro.channel()
            resp = repro.channel()
            repro.task() \
                .invoke(Server, req, resp, detach=True) \
                .invoke(Client, req, resp, sink)

        for eng in ("coroutine", "thread"):
            sink = []
            rep = repro.run(Top, sink, engine=eng)
            assert rep.ok and sink == [0, 2, 4, 6, 8], eng
