"""Graph metadata IR: validate / to_dot / dedup_factor on multi-level
graphs, plus the structural-hash definition dedup (no XLA — tier-1)."""

import pytest

from repro.core import channel, elaborate, task
from repro.core.errors import GraphValidationError


def _chain_top(n_mid: int = 2):
    """Top -> [Mid_i] -> Sink where each Mid spawns two Leaf children
    connected by a channel created *inside* Mid (same-parent rule)."""

    def Leaf(inp, out):
        for v in inp:
            out.write(v * 2)
        out.close()

    def Tail(inp, out):
        for v in inp:
            out.write(v + 1)
        out.close()

    def Mid(inp, out):
        inner = channel(capacity=4, name="inner")
        task().invoke(Leaf, inp, inner).invoke(Tail, inner, out)

    def Src(out):
        for v in range(4):
            out.write(v)
        out.close()

    def Sink(inp, acc: list):
        for v in inp:
            acc.append(v)

    acc: list = []

    def Top():
        chans = [channel(capacity=4, name=f"c{i}")
                 for i in range(n_mid + 1)]
        t = task().invoke(Src, chans[0])
        for i in range(n_mid):
            t = t.invoke(Mid, chans[i], chans[i + 1], name=f"Mid{i}")
        t.invoke(Sink, chans[n_mid], acc)

    return Top, acc


def test_multilevel_validate_and_counts():
    top, acc = _chain_top(n_mid=2)
    g = elaborate(top)                     # validates internally
    assert acc == [((v * 2) + 1) * 2 + 1 for v in range(4)]
    # two levels: Top at level 0, Src/Mid/Sink at 1, Leaf/Tail at 2
    levels = {i.level for i in g.instances}
    assert levels == {0, 1, 2}
    # definitions dedup across the two Mid subtrees: Leaf appears twice as
    # an instance but once as a definition (same for Tail and Mid)
    names = {d.name: d.n_instances for d in g.definitions}
    assert names["Leaf"] == 2 and names["Tail"] == 2 and names["Mid"] == 2
    assert g.n_instances == 1 + 1 + 2 + 1 + 4   # Top+Src+Mids+Sink+leaves
    assert g.dedup_factor() == pytest.approx(g.n_instances / g.n_tasks)
    assert all(d.defn_hash for d in g.definitions)


def test_definitions_dedup_recreated_closures():
    """Two *separately created* identical task closures are one definition
    under the structural hash (id(fn) would count two)."""

    def make_worker():
        def Worker(inp, acc: list):
            for v in inp:
                acc.append(v)
        return Worker

    acc: list = []

    def Top():
        a = channel(capacity=4, name="a")
        b = channel(capacity=4, name="b")

        def Src2(o1, o2):
            o1.write(1)
            o1.close()
            o2.write(2)
            o2.close()

        task().invoke(Src2, a, b) \
              .invoke(make_worker(), a, acc, name="w0") \
              .invoke(make_worker(), b, acc, name="w1")

    g = elaborate(top=Top)
    workers = [d for d in g.definitions if d.name == "Worker"]
    assert len(workers) == 1 and workers[0].n_instances == 2


def test_validate_reports_missing_endpoints():
    def Src(out, dangling):
        out.write(1)
        out.close()
        dangling.write(99)          # written but never read

    def Sink(inp):
        for _ in inp:
            pass

    def Top():
        c = channel(capacity=4, name="c")
        d = channel(capacity=4, name="dangling")
        task().invoke(Src, c, d).invoke(Sink, c)

    g = elaborate(Top, validate=False)
    with pytest.raises(GraphValidationError, match="dangling"):
        g.validate()


def test_validate_rejects_cross_parent_and_loopback():
    """Section 3.1.1: both endpoints under one parent, and no task may be
    its own peer.  The builder API binds endpoints at invoke time so these
    states can't arise from it — construct the IR directly."""
    from repro.core.channel import Channel
    from repro.core.graph import Graph
    from repro.core.task import TaskInstance

    def noop():
        pass

    top = TaskInstance(noop, (), {}, False, None, name="Top")
    mid = TaskInstance(noop, (), {}, False, top, name="Mid")
    leaf = TaskInstance(noop, (), {}, False, mid, name="Leaf")
    sink = TaskInstance(noop, (), {}, False, top, name="Sink")

    xp = Channel(2, "xparent")
    xp.producer, xp.consumer = leaf, sink       # level 2 -> level 1
    g = Graph(instances=[top, mid, leaf, sink], channels=[xp])
    with pytest.raises(GraphValidationError, match="different"):
        g.validate()

    loop = Channel(2, "loopy")
    loop.producer = loop.consumer = sink
    g2 = Graph(instances=[top, sink], channels=[loop])
    with pytest.raises(GraphValidationError, match="loops back"):
        g2.validate()


def test_to_dot_multilevel():
    top, _ = _chain_top(n_mid=1)
    g = elaborate(top)
    dot = g.to_dot()
    assert dot.startswith("digraph G {") and dot.endswith("}")
    # parent tasks render as boxes, leaves as ellipses
    assert "shape=box" in dot and "shape=ellipse" in dot
    # every validated channel appears as an edge with its capacity
    assert "inner/4" in dot and "c0/4" in dot
    # one node line per instance (edges carry labels too, so count shapes)
    assert dot.count("shape=") == g.n_instances


def test_summary_mentions_dedup():
    top, _ = _chain_top(n_mid=3)
    g = elaborate(top)
    s = g.summary()
    assert "dedup=" in s and "instances=" in s
