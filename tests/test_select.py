"""select()/wait_many coverage: the multi-port polling path (paper
Section 2.2's KPN extension) under both parallel engines.

Covered: already-ready early return, wake-on-push, wake-on-pop,
stale-epoch invalidation (two watched ports becoming ready for one
wake), and burst ops interleaving with select().
"""

import pytest

import repro

PARALLEL = ("coroutine", "thread")


# ---------------------------------------------------------------------------
# already-ready early return
# ---------------------------------------------------------------------------

def test_select_ready_returns_before_runtime():
    """select() on an already-ready stream returns without consulting the
    runtime at all — provable outside any engine, where a blocking wait
    would raise RuntimeError."""
    ch = repro.channel()
    ch._push(1)
    repro.select(repro.IStream(ch))            # readable: early return

    writable = repro.channel()
    repro.select(repro.OStream(writable))      # has room: early return

    empty = repro.channel()
    with pytest.raises(RuntimeError):
        repro.select(repro.IStream(empty))     # must block: needs a runtime


@pytest.mark.parametrize("eng", PARALLEL)
def test_select_ready_no_switch(eng):
    """A consumer that only selects on non-empty streams never parks."""
    def P(o: repro.OStream):
        for i in range(4):
            o.write(i)
        o.close()

    def C(i: repro.IStream, sink):
        while True:
            ok, eot = i.try_eot()
            if not ok:
                repro.select(i)
                continue
            if eot:
                i.open()
                return
            sink.append(i.read())

    def Top(sink):
        ch = repro.channel(capacity=8)
        repro.task().invoke(P, ch).invoke(C, ch, sink)

    sink = []
    rep = repro.run(Top, sink, engine=eng)
    assert rep.ok and sink == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# wake-on-push / wake-on-pop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eng", PARALLEL)
def test_select_wakes_on_push(eng):
    """A consumer parked in select() on two empty inputs is woken by a
    producer's push on either one."""
    def P1(o: repro.OStream):
        o.write("a")
        o.close()

    def P2(o: repro.OStream):
        o.write("b")
        o.close()

    def C(i1: repro.IStream, i2: repro.IStream, sink):
        done = [False, False]
        ins = [i1, i2]
        while not all(done):
            moved = False
            for s in (0, 1):
                if done[s]:
                    continue
                if ins[s].try_open():
                    done[s] = True
                    moved = True
                    continue
                ok, v = ins[s].try_read()
                if ok:
                    sink.append(v)
                    moved = True
            if not moved and not all(done):
                repro.select(*(ins[s] for s in (0, 1) if not done[s]))

    def Top(sink):
        c1 = repro.channel()
        c2 = repro.channel()
        repro.task().invoke(P1, c1).invoke(P2, c2).invoke(C, c1, c2, sink)

    sink = []
    rep = repro.run(Top, sink, engine=eng)
    assert rep.ok and sorted(sink) == ["a", "b"]


@pytest.mark.parametrize("eng", PARALLEL)
def test_select_wakes_on_pop(eng):
    """A producer parked in select() on a full output is woken when the
    consumer pops a token (writable-side wake)."""
    def P(o: repro.OStream, n):
        sent = 0
        while sent < n:
            if not o.try_write(sent):
                repro.select(o)        # park until the consumer makes room
                continue
            sent += 1
        o.close()

    def C(i: repro.IStream, sink):
        for v in i:
            sink.append(v)

    def Top(sink):
        ch = repro.channel(capacity=1)     # every token forces a park
        repro.task().invoke(P, ch, 5).invoke(C, ch, sink)

    sink = []
    rep = repro.run(Top, sink, engine=eng)
    assert rep.ok and sink == list(range(5))


# ---------------------------------------------------------------------------
# stale-epoch invalidation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eng", PARALLEL)
def test_select_two_ports_ready_single_wake(eng):
    """Both watched ports become ready while the selector is parked: the
    first wake must consume the registration; the second event must find
    it stale (no double-resume, no lost token).  A burst push makes both
    tokens arrive 'simultaneously' from the selector's point of view."""
    def P(o1: repro.OStream, o2: repro.OStream, rounds):
        for r in range(rounds):
            o1.write((1, r))
            o2.write((2, r))
        o1.close()
        o2.close()

    def C(i1: repro.IStream, i2: repro.IStream, sink):
        open_ = [False, False]
        ins = [i1, i2]
        while not all(open_):
            moved = False
            for s in (0, 1):
                if open_[s]:
                    continue
                if ins[s].try_open():
                    open_[s] = True
                    moved = True
                    continue
                got = ins[s].try_read_burst(8)
                if got:
                    sink.extend(got)
                    moved = True
            if not moved and not all(open_):
                repro.select(*(ins[s] for s in (0, 1) if not open_[s]))

    def Top(sink):
        c1 = repro.channel(capacity=2)
        c2 = repro.channel(capacity=2)
        repro.task().invoke(P, c1, c2, 6).invoke(C, c1, c2, sink)

    sink = []
    rep = repro.run(Top, sink, engine=eng)
    assert rep.ok, rep.error
    assert sorted(sink) == sorted([(p, r) for r in range(6) for p in (1, 2)])
    # per-stream order must still be FIFO
    assert [r for p, r in sink if p == 1] == list(range(6))
    assert [r for p, r in sink if p == 2] == list(range(6))


def test_select_stale_epoch_deterministic_schedule():
    """Under the coroutine engine the stale-epoch discipline must yield a
    deterministic switch count across repeated runs (a double-resume
    would desynchronize the baton and change — or hang — the schedule)."""
    def P(o1: repro.OStream, o2: repro.OStream):
        for r in range(8):
            (o1 if r % 2 else o2).write(r)
        o1.close()
        o2.close()

    def C(i1: repro.IStream, i2: repro.IStream, sink):
        open_ = [False, False]
        ins = [i1, i2]
        while not all(open_):
            moved = False
            for s in (0, 1):
                if open_[s]:
                    continue
                if ins[s].try_open():
                    open_[s] = True
                    moved = True
                    continue
                ok, v = ins[s].try_read()
                if ok:
                    sink.append(v)
                    moved = True
            if not moved and not all(open_):
                repro.select(*(ins[s] for s in (0, 1) if not open_[s]))

    def Top(sink):
        c1 = repro.channel(capacity=1)
        c2 = repro.channel(capacity=1)
        repro.task().invoke(P, c1, c2).invoke(C, c1, c2, sink)

    runs = []
    for _ in range(3):
        sink = []
        rep = repro.run(Top, sink, engine="coroutine")
        assert rep.ok and sorted(sink) == list(range(8))
        runs.append((rep.switches, tuple(sink)))
    assert runs[0] == runs[1] == runs[2]


# ---------------------------------------------------------------------------
# burst ops interleaving with select()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eng", PARALLEL)
def test_burst_writer_wakes_selector(eng):
    """write_burst on a channel whose consumer is parked in select() must
    wake it exactly like scalar writes do (one wake per burst)."""
    def P(o: repro.OStream):
        o.write_burst(list(range(10)))
        o.close()

    def C(i: repro.IStream, sink):
        while True:
            got = i.try_read_burst(4)
            if got:
                sink.extend(got)
                continue
            if i.try_open():
                return
            repro.select(i)

    def Top(sink):
        ch = repro.channel(capacity=4)
        repro.task().invoke(P, ch).invoke(C, ch, sink)

    sink = []
    rep = repro.run(Top, sink, engine=eng)
    assert rep.ok and sink == list(range(10))


@pytest.mark.parametrize("eng", PARALLEL)
def test_burst_reader_wakes_parked_writer(eng):
    """A producer parked in select() on a full channel must be woken by
    the consumer's burst read (writable-side burst wake)."""
    def P(o: repro.OStream, n):
        sent = 0
        while sent < n:
            k = o.try_write_burst(list(range(sent, n)))
            sent += k
            if sent < n and k == 0:
                repro.select(o)
        o.close()

    def C(i: repro.IStream, sink):
        sink.extend(i.read_transaction())

    def Top(sink):
        ch = repro.channel(capacity=3)
        repro.task().invoke(P, ch, 11).invoke(C, ch, sink)

    sink = []
    rep = repro.run(Top, sink, engine=eng)
    assert rep.ok, rep.error
    assert sink == list(range(11))
