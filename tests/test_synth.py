"""Whole-graph synthesis (repro.core.synth): the step-function task form,
its simulation twin, the CompiledEngine lowering, refusal diagnostics,
and the sim-vs-synth parity contract.

Fast tests (tier-1) cover the twin, the refusal paths (which never reach
XLA), channel element-spec enforcement, and the graph structural hash.
Anything that actually compiles a whole-graph program is marked slow and
runs in the CI synth-parity job.
"""

import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import repro
from repro import StepTask, SynthesisError, channel, mmap
from repro.core.errors import ChannelMisuse

jnp = pytest.importorskip("jax.numpy")

SRC = str(Path(__file__).resolve().parents[1] / "src")


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def relay_pipeline(n_tokens=64, stages=2, burst=8, capacity=16,
                   sink_extra=0, chan_kw=None):
    """Step-form Source -> stages x Relay -> Sink writing into an mmap."""
    fires = n_tokens // burst

    def source_step(k, out):
        out.write_burst(k * burst + jnp.arange(burst, dtype=jnp.int32))
        return k + 1

    def relay_step(state, inp, out):
        out.write_burst(inp.read_burst(burst))
        return state

    def sink_step(k, inp, res):
        res.write_burst(k * burst, inp.read_burst(burst))
        return k + 1

    Source = StepTask(source_step, steps=fires, init=jnp.int32(0),
                      name="Source")
    Relay = StepTask(relay_step, steps=fires, name="Relay")
    Sink = StepTask(sink_step, steps=fires + sink_extra, init=jnp.int32(0),
                    name="Sink")

    buf = np.zeros(n_tokens + sink_extra * burst, np.int32)
    res = mmap(buf, "res")
    kw = chan_kw if chan_kw is not None else dict(dtype=np.int32, shape=())

    def Top(res):
        chans = [channel(capacity, f"c{i}", **kw) for i in range(stages + 1)]
        t = repro.task().invoke(Source, chans[0])
        for s in range(stages):
            t = t.invoke(Relay, chans[s], chans[s + 1], name=f"Relay{s}")
        t.invoke(Sink, chans[stages], res)

    return Top, (res,), buf


# ---------------------------------------------------------------------------
# the simulation twin (fast)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["sequential", "thread", "coroutine"])
def test_twin_runs_on_every_simulation_engine(engine):
    top, args, buf = relay_pipeline(n_tokens=32, burst=8, capacity=32)
    rep = repro.ENGINES[engine]().run(top, *args)
    assert rep.ok, rep.error
    assert np.array_equal(buf, np.arange(32))


def test_twin_phases_run_in_order():
    log = []

    def w(state, out):
        log.append("warmup")
        out.write(jnp.int32(0))
        return state

    def s(state, out):
        log.append("step")
        out.write(jnp.int32(1))
        return state

    def f(state, out):
        log.append("flush")
        out.write(jnp.int32(2))
        return state

    def sink(k, inp, res):
        res[k] = inp.read()
        return k + 1

    T = StepTask(s, steps=2, warmup=w, flush=f, name="T")
    S = StepTask(sink, steps=4, init=jnp.int32(0), name="S")
    assert T.total_fires == 4
    buf = np.zeros(4, np.int32)
    res = mmap(buf, "r")

    def Top(res):
        c = channel(4, "c", dtype=np.int32, shape=())
        repro.task().invoke(T, c).invoke(S, c, res)

    rep = repro.ENGINES["coroutine"]().run(Top, res)
    assert rep.ok
    assert log == ["warmup", "step", "step", "flush"]
    assert list(buf) == [0, 1, 1, 2]


def test_twin_read_burst_refuses_eot():
    def closer(out):
        out.write_burst([1, 2])
        out.close()

    def sink_step(state, inp):
        inp.read_burst(4)
        return state

    S = StepTask(sink_step, steps=1, name="S")

    def Top():
        c = channel(8, "c")
        repro.task().invoke(closer, c).invoke(S, c)

    rep = repro.ENGINES["coroutine"]().run(Top)
    assert not rep.ok
    assert "terminate by firing counts" in rep.error


def test_step_task_signature_binds_named_ports():
    def body(state, inp, out, gain: float):
        out.write(inp.read() * gain)
        return state

    t = StepTask(body, steps=3, name="Scale")
    params = list(t.__signature__.parameters)
    assert params == ["inp", "out", "gain"]
    assert t.__name__ == "Scale"


# ---------------------------------------------------------------------------
# refusal diagnostics (fast: none of these reach XLA compilation)
# ---------------------------------------------------------------------------

def test_refuses_non_step_leaf_naming_the_task():
    from repro.apps import network
    with pytest.raises(SynthesisError) as e:
        network.run_step("compiled")
    msg = str(e.value)
    assert "SW0_0" in msg and "step-function form" in msg


def test_network_step_graph_still_simulates():
    from repro.apps import network
    r = network.run_step("coroutine")
    assert r.ok and r.correct


def test_refuses_unspecced_channel():
    top, args, _ = relay_pipeline(chan_kw={})
    with pytest.raises(SynthesisError, match="element spec"):
        repro.ENGINES["compiled"]().run(top, *args)


def test_refuses_unbounded_async_depth():
    # bounded-depth ports lower to the compiled latency queue; only an
    # unbounded in-flight window (depth=None) has no static carry shape
    from repro.core import async_mmap

    def s(state, port):
        return state

    S = StepTask(s, steps=1, name="S")
    port = async_mmap(np.zeros(4, np.float32), depth=None)

    def Top(port):
        repro.task().invoke(S, port)

    with pytest.raises(SynthesisError, match="bounded depth"):
        repro.ENGINES["compiled"]().run(Top, port)


def test_refuses_read_write_async_port():
    # read-after-write through one port resolves by response timing, so a
    # port is read-only or write-only per synthesized graph
    from repro.core import async_mmap

    def s(k, port):
        port.read_addr.write(jnp.int32(0))
        port.write_addr.write(jnp.int32(1))
        port.write_data.write(port.read_data.read())
        port.write_resp.read()
        return k

    S = StepTask(s, steps=1, init=jnp.int32(0), name="S")
    port = async_mmap(np.zeros(4, np.float32), depth=2)

    def Top(port):
        repro.task().invoke(S, port)

    with pytest.raises(SynthesisError, match="one port per direction"):
        repro.ENGINES["compiled"]().run(Top, port)


def test_refuses_read_pipelined_in_step_body():
    from repro.core import async_mmap

    def s(k, port):
        port.read_pipelined(jnp.arange(2))
        return k

    S = StepTask(s, steps=1, init=jnp.int32(0), name="S")
    port = async_mmap(np.zeros(4, np.float32), depth=2)

    def Top(port):
        repro.task().invoke(S, port)

    with pytest.raises(SynthesisError, match="read_pipelined"):
        repro.ENGINES["compiled"]().run(Top, port)


def test_async_depth_in_structural_hash():
    # latency/depth size the lowered queue: twins differing only there
    # must not share a compiled program
    from repro.core import async_mmap
    from repro.core.synth import elaborate_step_graph

    def s(k, port):
        port.read_addr.write(k)
        port.read_data.read()
        return k + 1

    def build(depth, latency=4):
        S = StepTask(s, steps=1, init=jnp.int32(0), name="S")
        port = async_mmap(np.zeros(4, np.float32), depth=depth,
                          latency=latency, name="m")

        def Top(port):
            repro.task().invoke(S, port)
        _, graph, _ = elaborate_step_graph(Top, port)
        return graph.structural_hash()

    assert build(1) != build(4)
    assert build(4, latency=2) != build(4, latency=8)
    assert build(4) == build(4)


def test_refuses_data_dependent_burst_size():
    def bad(k, inp, out):
        n = inp.read()
        out.write_burst(inp.read_burst(n))     # traced size
        return k

    B = StepTask(bad, steps=1, init=jnp.int32(0), name="Bad")

    def Top():
        a = channel(8, "a", dtype=np.int32, shape=())
        b = channel(8, "b", dtype=np.int32, shape=())
        src = StepTask(lambda k, o: (o.write_burst(jnp.arange(4,
                       dtype=jnp.int32)), k + 1)[1], steps=1,
                       init=jnp.int32(0), name="Src")
        repro.task().invoke(src, a).invoke(B, a, b)

    with pytest.raises(SynthesisError, match="data-dependent"):
        repro.ENGINES["compiled"]().run(Top)


def test_refuses_wrong_token_shape():
    def bad(state, out):
        out.write(jnp.zeros((3, 3), jnp.float32))
        return state

    B = StepTask(bad, steps=1, name="Bad")

    def sink(state, inp):
        inp.read()
        return state

    S = StepTask(sink, steps=1, name="S")

    def Top():
        c = channel(2, "c", dtype=np.float32, shape=(2, 2))
        repro.task().invoke(B, c).invoke(S, c)

    with pytest.raises(SynthesisError, match=r"shape \(3, 3\)"):
        repro.ENGINES["compiled"]().run(Top)


def test_refuses_reads_beyond_capacity():
    top, args, _ = relay_pipeline(burst=8, capacity=4)
    with pytest.raises(SynthesisError, match="could never fire"):
        repro.ENGINES["compiled"]().run(top, *args)


def test_refuses_close_outputs():
    def s(k, out):
        out.write(jnp.int32(0))
        return k

    T = StepTask(s, steps=1, init=jnp.int32(0), close_outputs=True,
                 name="T")

    def sink(k, inp):
        inp.read()
        return k

    S = StepTask(sink, steps=1, init=jnp.int32(0), name="S")

    def Top():
        c = channel(2, "c", dtype=np.int32, shape=())
        repro.task().invoke(T, c).invoke(S, c)

    with pytest.raises(SynthesisError, match="EoT"):
        repro.ENGINES["compiled"]().run(Top)


def test_refuses_cross_task_mmap_read_after_write():
    m = mmap(np.zeros(4, np.float32), "shared")

    def writer(state, m):
        m[0] = jnp.float32(1.0)
        return state

    def reader(state, m, out):
        out.write(m[0])
        return state

    W = StepTask(writer, steps=1, name="W")
    R = StepTask(reader, steps=1, name="R")

    def sink(state, inp):
        inp.read()
        return state

    S = StepTask(sink, steps=1, name="S")

    def Top(m):
        c = channel(2, "c", dtype=np.float32, shape=())
        repro.task().invoke(W, m).invoke(R, m, c).invoke(S, c)

    with pytest.raises(SynthesisError, match="schedule-dependent"):
        repro.ENGINES["compiled"]().run(Top, m)


def test_refuses_unstable_state_spec():
    def grow(state, out):
        out.write(jnp.int32(0))
        return jnp.zeros(int(state.shape[0]) + 1, jnp.int32)

    G = StepTask(grow, steps=2, init=jnp.zeros(1, jnp.int32), name="G")

    def sink(state, inp):
        inp.read()
        return state

    S = StepTask(sink, steps=2, name="S")

    def Top():
        c = channel(2, "c", dtype=np.int32, shape=())
        repro.task().invoke(G, c).invoke(S, c)

    with pytest.raises(SynthesisError, match="state"):
        repro.ENGINES["compiled"]().run(Top)


# ---------------------------------------------------------------------------
# channel element-spec enforcement in the simulators (fast)
# ---------------------------------------------------------------------------

def test_channel_spec_enforced_under_track_stats():
    def bad(out):
        out.write(np.zeros((3,), np.float64))

    def consumer(inp):
        inp.read()

    def Top():
        c = channel(2, "typed", dtype=np.float32, shape=(3,))
        repro.task().invoke(bad, c, name="BadProducer") \
            .invoke(consumer, c)

    rep = repro.ENGINES["coroutine"](track_stats=True).run(Top)
    assert not rep.ok
    assert "typed" in rep.error and "BadProducer" in rep.error \
        and "float64" in rep.error


def test_channel_spec_shape_mismatch_burst():
    def bad(out):
        out.write_burst([np.zeros(2, np.float32)])

    def Top():
        c = channel(2, "typed", dtype=np.float32, shape=(3,))
        repro.task().invoke(bad, c, name="BadBurst") \
            .invoke(lambda i: i.read(), c)

    rep = repro.ENGINES["coroutine"](track_stats=True).run(Top)
    assert not rep.ok and "shape" in rep.error


def test_channel_spec_allows_matching_and_python_scalars():
    def good(out):
        out.write(np.float32(1.5))
        out.write(2.5)                       # kind-checked Python scalar
        out.close()

    def consume(inp):
        assert list(inp) == [np.float32(1.5), 2.5]

    def Top():
        c = channel(4, "typed", dtype=np.float32, shape=())
        repro.task().invoke(good, c).invoke(consume, c)

    rep = repro.ENGINES["coroutine"](track_stats=True).run(Top)
    assert rep.ok, rep.error


def test_channel_spec_ignored_without_track_stats():
    def sloppy(out):
        out.write("not a float")
        out.close()

    def consume(inp):
        list(inp)

    def Top():
        c = channel(4, "typed", dtype=np.float32, shape=())
        repro.task().invoke(sloppy, c).invoke(consume, c)

    rep = repro.ENGINES["coroutine"]().run(Top)   # default: no checks
    assert rep.ok


def test_channel_capacity_must_be_static_int():
    with pytest.raises(ValueError):
        channel(0)
    with pytest.raises(ValueError):
        channel(2.5)


# ---------------------------------------------------------------------------
# channel table + graph structural hash (fast)
# ---------------------------------------------------------------------------

def _tiny_graph(cap=4, val=0.0):
    a = mmap(np.full(4, val, np.float32), "a")

    def src(k, a, out):
        out.write(a[k])
        return k + 1

    def snk(state, inp):
        inp.read()
        return state

    S = StepTask(src, steps=4, init=jnp.int32(0), name="Src")
    K = StepTask(snk, steps=4, name="Snk")

    def Top(a):
        c = channel(cap, "c", dtype=np.float32, shape=())
        repro.task().invoke(S, a, c).invoke(K, c)

    return Top, (a,)


def test_channel_info_table():
    top, args, _ = relay_pipeline(n_tokens=16, stages=1, burst=8,
                                  capacity=16)
    g = repro.elaborate(top, *args, engine="coroutine")
    info = {ci.name: ci for ci in g.channel_info}
    assert info["c0"].capacity == 16
    assert info["c0"].shape == ()
    assert str(info["c0"].dtype) == "int32"
    assert info["c0"].producer and info["c0"].consumer


def test_structural_hash_stable_and_sensitive():
    g1 = repro.elaborate(*_tiny_graph(), engine="coroutine")
    g2 = repro.elaborate(*_tiny_graph(), engine="coroutine")
    # same structure, fresh objects -> same hash
    assert g1.structural_hash() == g2.structural_hash()
    # mmap *values* are excluded (aval-keyed, like the compile cache)
    g3 = repro.elaborate(*_tiny_graph(val=7.0), engine="coroutine")
    assert g1.structural_hash() == g3.structural_hash()
    # capacity is part of the channel type
    g4 = repro.elaborate(*_tiny_graph(cap=8), engine="coroutine")
    assert g1.structural_hash() != g4.structural_hash()


# ---------------------------------------------------------------------------
# lowered execution (slow: compiles whole-graph XLA programs)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_compiled_relay_pipeline_end_to_end():
    top, args, buf = relay_pipeline(n_tokens=64, burst=8, capacity=16)
    eng = repro.ENGINES["compiled"](cache=False)
    rep = eng.run(top, *args)
    assert rep.ok, rep.error
    assert np.array_equal(buf, np.arange(64))
    assert rep.engine == "compiled"
    assert rep.switches == eng.n_sweeps > 0
    assert rep.tokens > 0
    assert all(st == "finished" for _, st in rep.instances)
    occ = {name: mo for name, _, mo in rep.channels}
    assert max(occ.values()) > 0


@pytest.mark.slow
def test_compiled_deadlock_reports_blocked_task():
    top, args, _ = relay_pipeline(sink_extra=1)
    rep = repro.ENGINES["compiled"](cache=False).run(top, *args)
    assert not rep.ok
    assert "Sink" in rep.error and "stalled" in rep.error
    states = dict(rep.instances)
    assert any(v == "blocked" for v in states.values())
    # unified watchdog: the compiled engine emits the same structured
    # DeadlockReport the software engines do (reason "stall")
    assert rep.deadlock is not None
    assert rep.deadlock.engine == "compiled"
    assert rep.deadlock.reason == "stall"
    assert any("Sink" in t for t, _ in rep.deadlock.blocked)


@pytest.mark.slow
@pytest.mark.parametrize("app,out_arg", [
    ("gemm", None),              # mmap-fed systolic array, array tokens
    ("gaussian", 1),             # burst-heavy stencil chain
    ("page_rank", 1),            # mmap-fed feedback loop
])
def test_app_parity_bit_identical(app, out_arg):
    from repro import apps
    mod = getattr(apps, app)
    t1, a1, c1 = mod.build_step()
    rep1 = repro.ENGINES["coroutine"]().run(t1, *a1)
    assert rep1.ok and c1()[0]
    t2, a2, c2 = mod.build_step()
    eng = repro.ENGINES["compiled"]()
    rep2 = eng.run(t2, *a2)
    assert rep2.ok and c2()[0]
    if out_arg is None:          # gemm: per-row C views
        pairs = list(zip(a1[2], a2[2]))
    else:
        pairs = [(a1[out_arg], a2[out_arg])]
    for m1, m2 in pairs:
        assert np.array_equal(m1.data, m2.data), \
            f"{app}: compiled output != coroutine twin output"


@pytest.mark.slow
def test_page_rank_step_feedback_fails_sequential_runs_compiled():
    from repro.apps import page_rank
    t, a, _ = page_rank.build_step(n_iters=3)
    rep = repro.ENGINES["sequential"]().run(t, *a)
    assert not rep.ok                       # feedback loop (paper Fig. 7)
    t2, a2, c2 = page_rank.build_step(n_iters=3)
    rep2 = repro.ENGINES["compiled"]().run(t2, *a2)
    assert rep2.ok and c2()[0]


@pytest.mark.slow
def test_whole_graph_cache_key_is_value_independent(tmp_path):
    from repro.core.compile_cache import CompileCache
    cc = CompileCache(root=tmp_path)
    keys = []
    for seed in (0, 1):
        from repro.apps import gaussian
        t, a, _ = gaussian.build_step(h=6, w=6, iters=1, seed=seed)
        eng = repro.ENGINES["compiled"](cache=cc)
        assert eng.run(t, *a).ok
        keys.append(eng.compile_key)
    assert keys[0] == keys[1]
    assert cc.stats.misses == 1             # second run: pure hit


@pytest.mark.slow
def test_track_stats_fills_mmap_and_channel_counters():
    from repro.apps import gaussian
    t, a, _ = gaussian.build_step(h=6, w=6, iters=1)
    eng = repro.ENGINES["compiled"](track_stats=True)
    rep = eng.run(t, *a)
    assert rep.ok
    ifaces = {name: stats for name, _, stats in rep.interfaces}
    assert ifaces["img"]["loads"] > 0
    assert ifaces["result"]["store_elems"] == 36
    assert rep.tokens > 0


@pytest.mark.slow
def test_track_stats_mmap_counters_match_twin():
    """The compiled engine's reconstructed interface stats must agree
    with the twin's per-transfer counters (op counts AND element counts —
    a collector doing P stores in one firing reports P, not 1)."""
    from repro.apps import gemm
    t1, a1, _ = gemm.build_step(P=2, n=4, K=2)
    rep1 = repro.ENGINES["coroutine"](track_stats=True).run(t1, *a1)
    assert rep1.ok
    t2, a2, _ = gemm.build_step(P=2, n=4, K=2)
    rep2 = repro.ENGINES["compiled"](track_stats=True).run(t2, *a2)
    assert rep2.ok
    twin = {name: stats for name, _, stats in rep1.interfaces}
    comp = {name: stats for name, _, stats in rep2.interfaces}
    assert twin.keys() == comp.keys()
    for name in twin:
        assert twin[name] == comp[name], (name, twin[name], comp[name])


@pytest.mark.slow
def test_x64_channel_dtype_canonicalizes_not_refuses():
    """A float64 channel declaration is canonicalized to the device dtype
    (f32 when 64-bit mode is off) instead of blaming the task for writing
    the tokens jax actually produces."""
    top, args, buf = relay_pipeline(
        n_tokens=16, stages=1, burst=8, capacity=16,
        chan_kw=dict(dtype=np.int64, shape=()))
    rep = repro.ENGINES["compiled"](cache=False).run(top, *args)
    assert rep.ok, rep.error
    assert np.array_equal(buf, np.arange(16))


@pytest.mark.slow
def test_second_process_performs_zero_xla_compiles(tmp_path):
    """The PR-2 contract extended to whole-graph lowerings: a fresh
    process re-running the same graph loads the serialized executable
    from the content-addressed store."""
    prog = textwrap.dedent(f"""
        import sys
        sys.path.insert(0, {SRC!r})
        import repro
        from repro.core.compile_cache import CompileCache
        from repro.apps import gaussian
        cc = CompileCache(root={str(tmp_path)!r})
        t, a, c = gaussian.build_step(h=6, w=6, iters=2)
        eng = repro.ENGINES["compiled"](cache=cc)
        rep = eng.run(t, *a)
        assert rep.ok and c()[0]
        print("SOURCE", eng.compile_source, "KEY", eng.compile_key)
    """)
    outs = []
    for _ in range(2):
        r = subprocess.run([sys.executable, "-c", prog],
                           capture_output=True, text=True, timeout=300)
        assert r.returncode == 0, r.stderr[-2000:]
        outs.append(r.stdout)
    assert "SOURCE compiled" in outs[0]
    assert "SOURCE disk" in outs[1]          # zero XLA compiles
    key0 = outs[0].split("KEY ")[1].strip()
    key1 = outs[1].split("KEY ")[1].strip()
    assert key0 == key1


# ---------------------------------------------------------------------------
# async_mmap synthesis: the compiled latency queue (slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("depth", [1, 4])
def test_gemm_async_compiled_matches_twin(depth):
    """The compiled latency queue must be a *data* twin of the simulator's
    AsyncMMap pump: the C blocks written through the ports are bit-
    identical, and the issue-ahead window actually opens at depth > 1."""
    from repro.apps import gemm
    outs = {}
    for eng in ("coroutine", "compiled"):
        top, args, check = gemm.build_step_async(P=2, n=4, K=4, depth=depth)
        rep = repro.ENGINES[eng]().run(top, *args)
        assert rep.ok, rep.error
        assert check()[0]
        _, a_ports, c_ports = args
        outs[eng] = np.stack([np.asarray(p.data) for p in c_ports])
        if eng == "compiled":
            for p in a_ports:
                assert p.read_reqs == p.read_resps == 4
                if depth == 1:
                    assert p.max_outstanding_reads == 1
                else:
                    assert p.max_outstanding_reads > 1
            for p in c_ports:
                assert p.write_reqs == p.write_resps == 2
    assert outs["coroutine"].tobytes() == outs["compiled"].tobytes()


@pytest.mark.slow
@pytest.mark.parametrize("depth", [1, 4])
def test_page_rank_async_compiled_matches_twin(depth):
    """Async-fed edges around the rank feedback loop: compiled ranks are
    bit-identical to the coroutine twin's at any in-flight depth."""
    from repro.apps import page_rank
    outs = {}
    for eng in ("coroutine", "compiled"):
        top, args, check = page_rank.build_step_async(
            n_vertices=16, n_edges=48, n_pe=2, n_iters=4, edge_depth=depth)
        rep = repro.ENGINES[eng]().run(top, *args)
        assert rep.ok, rep.error
        assert check()[0]
        _, out_mm, _, eports, _ = args
        outs[eng] = np.asarray(out_mm.data).copy()
        if eng == "compiled":
            for p in eports:
                assert p.read_reqs == p.read_resps == 4 * len(p)
                if depth == 1:
                    assert p.max_outstanding_reads == 1
                else:
                    assert p.max_outstanding_reads > 1
    assert outs["coroutine"].tobytes() == outs["compiled"].tobytes()


@pytest.mark.slow
def test_ring_impl_interpret_matches_xla_pipeline():
    """The same graph lowered with the Pallas interconnect kernels (under
    the interpreter off-TPU) produces the XLA reference path's exact
    output buffer."""
    bufs = {}
    for impl in ("xla", "interpret"):
        top, args, buf = relay_pipeline(n_tokens=32, stages=2, burst=4,
                                        capacity=8)
        rep = repro.ENGINES["compiled"](cache=False, ring_impl=impl).run(
            top, *args)
        assert rep.ok, rep.error
        bufs[impl] = buf.copy()
    assert np.array_equal(bufs["xla"], bufs["interpret"])


@pytest.mark.slow
def test_ring_impl_env_override(monkeypatch):
    """$REPRO_RING_IMPL selects the interconnect path when the engine
    doesn't force one."""
    from repro.kernels.ring import RING_ENV
    monkeypatch.setenv(RING_ENV, "interpret")
    top, args, buf = relay_pipeline(n_tokens=16, stages=1, burst=4,
                                    capacity=8)
    rep = repro.ENGINES["compiled"](cache=False).run(top, *args)
    assert rep.ok, rep.error
    assert np.array_equal(buf, np.arange(16))
