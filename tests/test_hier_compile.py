"""Hierarchical compilation (C3): dedup correctness + dataflow execution."""

import jax
import jax.numpy as jnp
import numpy as np

import pytest

from repro.core.hier_compile import (DataflowProgram, StageInstance,
                                     compile_stages)

pytestmark = pytest.mark.slow  # JAX-compile-heavy: excluded from the tier-1 default run


def f_double(x):
    return x * 2.0


def f_inc(x):
    return x + 1.0


def test_dedup_counts():
    x = jnp.ones((8, 8))
    insts = [StageInstance(fn=f_double, args=(x,), name=f"d{i}")
             for i in range(5)]
    insts += [StageInstance(fn=f_inc, args=(x,), name="i0")]
    rep = compile_stages(insts, mode="hierarchical")
    assert rep.n_instances == 6 and rep.n_unique == 2
    assert all(i.executable is not None for i in insts)
    # all instances of the same definition share one executable object
    assert insts[0].executable is insts[4].executable
    assert insts[0].executable is not insts[5].executable


def test_shape_signature_splits_definitions():
    """Same fn, different input shapes -> distinct compiled variants."""
    a = jnp.ones((4, 4))
    b = jnp.ones((8, 8))
    insts = [StageInstance(fn=f_double, args=(a,)),
             StageInstance(fn=f_double, args=(b,))]
    rep = compile_stages(insts, mode="hierarchical")
    assert rep.n_unique == 2


def test_monolithic_and_hierarchical_agree():
    x = jnp.full((4, 4), 3.0)
    for mode in ("monolithic", "hierarchical"):
        insts = [StageInstance(fn=f_double, args=()),
                 StageInstance(fn=f_inc, args=()),
                 StageInstance(fn=f_double, args=())]
        # wire a 3-stage chain: x*2 + 1, then *2
        for i in insts:
            i.args = ()
        prog = DataflowProgram(instances=insts,
                               wiring={1: [0], 2: [1]})
        compile_stages(
            [StageInstance(fn=i.fn, args=(x,), name=str(k))
             for k, i in enumerate(insts)], mode=mode)
        # executables compiled per shape; run program uncompiled for wiring
        out = prog(x)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray((x * 2 + 1) * 2))


def test_hierarchical_faster_or_equal_with_dedup():
    """With 12 instances of 2 definitions, hierarchical must do fewer
    compilations (6x dedup); wall-clock on 1 core reflects that."""
    jax.clear_caches()
    x = jnp.ones((64, 64))
    insts_m = [StageInstance(fn=(f_double if i % 2 else f_inc), args=(x,))
               for i in range(12)]
    rep_m = compile_stages(insts_m, mode="monolithic")
    jax.clear_caches()
    insts_h = [StageInstance(fn=(f_double if i % 2 else f_inc), args=(x,))
               for i in range(12)]
    rep_h = compile_stages(insts_h, mode="hierarchical")
    assert rep_h.n_unique == 2
    assert len(rep_h.per_key_s) == 2 and len(rep_m.per_key_s) == 12
