"""Hierarchical compilation (C3): dedup correctness + dataflow execution."""

import jax
import jax.numpy as jnp
import numpy as np

import pytest

from repro.core.hier_compile import (DataflowProgram, StageInstance,
                                     compile_stages)

pytestmark = pytest.mark.slow  # JAX-compile-heavy: excluded from the tier-1 default run


def f_double(x):
    return x * 2.0


def f_inc(x):
    return x + 1.0


def test_dedup_counts():
    x = jnp.ones((8, 8))
    insts = [StageInstance(fn=f_double, args=(x,), name=f"d{i}")
             for i in range(5)]
    insts += [StageInstance(fn=f_inc, args=(x,), name="i0")]
    rep = compile_stages(insts, mode="hierarchical", cache=False)
    assert rep.n_instances == 6 and rep.n_unique == 2
    assert all(i.executable is not None for i in insts)
    # all instances of the same definition share one executable object
    assert insts[0].executable is insts[4].executable
    assert insts[0].executable is not insts[5].executable


def test_shape_signature_splits_definitions():
    """Same fn, different input shapes -> distinct compiled variants."""
    a = jnp.ones((4, 4))
    b = jnp.ones((8, 8))
    insts = [StageInstance(fn=f_double, args=(a,)),
             StageInstance(fn=f_double, args=(b,))]
    rep = compile_stages(insts, mode="hierarchical", cache=False)
    assert rep.n_unique == 2


def test_monolithic_and_hierarchical_agree():
    x = jnp.full((4, 4), 3.0)
    for mode in ("monolithic", "hierarchical"):
        insts = [StageInstance(fn=f_double, args=()),
                 StageInstance(fn=f_inc, args=()),
                 StageInstance(fn=f_double, args=())]
        # wire a 3-stage chain: x*2 + 1, then *2
        for i in insts:
            i.args = ()
        prog = DataflowProgram(instances=insts,
                               wiring={1: [0], 2: [1]})
        compile_stages(
            [StageInstance(fn=i.fn, args=(x,), name=str(k))
             for k, i in enumerate(insts)], mode=mode, cache=False)
        # executables compiled per shape; run program uncompiled for wiring
        out = prog(x)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray((x * 2 + 1) * 2))


def test_hierarchical_faster_or_equal_with_dedup():
    """With 12 instances of 2 definitions, hierarchical must do fewer
    compilations (6x dedup); wall-clock on 1 core reflects that."""
    jax.clear_caches()
    x = jnp.ones((64, 64))
    insts_m = [StageInstance(fn=(f_double if i % 2 else f_inc), args=(x,))
               for i in range(12)]
    rep_m = compile_stages(insts_m, mode="monolithic")
    jax.clear_caches()
    insts_h = [StageInstance(fn=(f_double if i % 2 else f_inc), args=(x,))
               for i in range(12)]
    # cache=False: don't write persistent executables into ~/.cache as a
    # test side effect (and keep the compile-count comparison honest)
    rep_h = compile_stages(insts_h, mode="hierarchical", cache=False)
    assert rep_h.n_unique == 2
    assert len(rep_h.per_key_s) == 2 and len(rep_m.per_key_s) == 12


# ---------------------------------------------------------------------------
# DataflowProgram input feeding / sink collection
# ---------------------------------------------------------------------------

def test_dataflow_multi_source_feeds_by_index():
    """Inputs map to source stages by stage index, not arrival order of a
    shrinking feed list (the old ``feed.pop(0)`` silently misassigned)."""
    insts = [StageInstance(fn=f_double),        # source 0
             StageInstance(fn=f_inc),           # source 1
             StageInstance(fn=lambda a, b: a + b)]
    prog = DataflowProgram(instances=insts, wiring={2: [0, 1]})
    assert prog.sources() == [0, 1] and prog.sinks() == [2]
    a = jnp.full((2, 2), 3.0)
    b = jnp.full((2, 2), 10.0)
    out = prog(a, b)                             # (a*2) + (b+1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a * 2 + b + 1))


def test_dataflow_arity_mismatch_raises():
    insts = [StageInstance(fn=f_double), StageInstance(fn=f_inc),
             StageInstance(fn=lambda a, b: a + b)]
    prog = DataflowProgram(instances=insts, wiring={2: [0, 1]})
    x = jnp.ones((2, 2))
    with pytest.raises(ValueError, match="source stage"):
        prog(x)                                  # too few
    with pytest.raises(ValueError, match="source stage"):
        prog(x, x, x)                            # extras are not dropped


def test_dataflow_returns_all_sinks():
    """A fan-out graph returns every sink's output, not whichever stage
    happens to be listed last."""
    insts = [StageInstance(fn=f_inc),            # source
             StageInstance(fn=f_double),         # sink A
             StageInstance(fn=lambda x: x - 1.0)]  # sink B
    prog = DataflowProgram(instances=insts, wiring={1: [0], 2: [0]})
    assert prog.sinks() == [1, 2]
    x = jnp.full((2, 2), 4.0)
    out_a, out_b = prog(x)
    np.testing.assert_allclose(np.asarray(out_a), np.asarray((x + 1) * 2))
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(x))


def test_dataflow_explicit_source_indices():
    """Arg-bound generators opt out of graph feeding explicitly."""
    insts = [StageInstance(fn=f_inc, args=(jnp.ones((2, 2)),)),
             StageInstance(fn=f_double)]
    prog = DataflowProgram(instances=insts, wiring={1: [0]},
                           source_indices=[])
    np.testing.assert_allclose(np.asarray(prog()), 4.0)


# ---------------------------------------------------------------------------
# incremental recompilation (QoR-tuning loop)
# ---------------------------------------------------------------------------

def test_build_dataflow_preserves_compile_keys(tmp_path):
    """build_dataflow strips input placeholders on *copies*: the caller's
    instances keep their compile-time args, so the same list still keys
    correctly in a later incremental compile_stages."""
    from repro.core.compile_cache import CompileCache
    from repro.core.hier_compile import build_dataflow

    def make(c):
        def f(x):
            return x * c
        return f

    x = jnp.ones((4, 4))
    insts = [StageInstance(fn=make(2.0), args=(x,), name="s0"),
             StageInstance(fn=make(3.0), args=(x,), name="s1")]
    rep = compile_stages(insts, cache=CompileCache(root=tmp_path))
    prog = build_dataflow(insts, {1: [0]})
    np.testing.assert_allclose(np.asarray(prog(x)), np.asarray(x) * 6.0)
    assert insts[0].args == (x,)            # originals untouched
    rep2 = compile_stages(insts, cache=CompileCache(root=tmp_path / "b"),
                          prev=rep)
    assert rep2.n_reused == 2 and rep2.n_compiled == 0


def test_monolithic_report_works_as_prev():
    """Even a baseline (monolithic) report carries structural-keyed
    executables, so prev= reuse isn't silently void for one mode."""
    x = jnp.ones((4, 4))
    rep_m = compile_stages([StageInstance(fn=f_double, args=(x,))],
                           mode="monolithic")
    rep = compile_stages([StageInstance(fn=f_double, args=(x,))],
                         cache=False, prev=rep_m)
    assert rep.n_reused == 1 and rep.n_compiled == 0


def test_incremental_prev_report_reuses_clean_definitions(tmp_path):
    from repro.core.compile_cache import CompileCache

    def make(c):
        def f(x):
            return x * c
        return f

    x = jnp.ones((8, 8))

    def insts(coefs):
        return [StageInstance(fn=make(c), args=(x,)) for c in coefs]

    cc = CompileCache(root=tmp_path)
    prev = compile_stages(insts([1.0, 2.0]), cache=cc)
    assert prev.n_compiled == 2
    rep = compile_stages(insts([1.0, 5.0]),
                         cache=CompileCache(root=tmp_path / "b"), prev=prev)
    assert rep.n_reused == 1 and rep.n_compiled == 1
    # the reused executable is the very object from the previous report
    clean_key = StageInstance(fn=make(1.0), args=(x,)).key
    assert rep.executables[clean_key] is prev.executables[clean_key]
