"""Direct unit tests for repro.ckpt.manager.

Until now the checkpoint manager was only exercised indirectly through
tests/test_distributed.py's elastic-restart scenario; these pin its core
contracts in isolation: the atomic tmp->rename publish, corrupt/
incomplete-step recovery in restore_latest, keep-last-k GC, and the
async save(blocking=False) + wait() ordering.
"""

import json
import threading
import time

import numpy as np
import pytest

pytest.importorskip("jax")   # device_get only — no XLA compiles: tier-1

from repro.ckpt import CheckpointManager  # noqa: E402
from repro.ckpt.manager import load_pytree, save_pytree  # noqa: E402


def _params(v=1.0):
    return {"w": np.full((3, 2), v, np.float32),
            "b": {"inner": np.arange(4, dtype=np.int32)}}


def _opt(v=0.0):
    return {"mu": np.full((3, 2), v, np.float32)}


# ---------------------------------------------------------------------------
# atomic publish
# ---------------------------------------------------------------------------

def test_save_publishes_atomically_no_tmp_left(tmp_path):
    mgr = CheckpointManager(tmp_path)
    path = mgr.save(3, _params(), _opt(), extra={"lr": 0.1})
    assert path.name == "step_00000003"
    assert path.is_dir()
    assert (path / "DONE").exists()
    # no .tmp staging directory survives a successful publish
    assert not list(tmp_path.glob("*.tmp"))
    man = json.loads((path / "DONE").read_text())
    assert man["step"] == 3 and man["extra"] == {"lr": 0.1}
    # every manifest-listed leaf file exists
    for section in ("params", "opt_state"):
        for entry in man[section].values():
            assert (path / section / entry["file"]).exists()


def test_restore_round_trips_values_and_extra(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(7, _params(2.5), _opt(0.5), extra={"tokens": 123})
    p, o, extra = mgr.restore(7, _params(), _opt())
    assert np.array_equal(np.asarray(p["w"]), np.full((3, 2), 2.5))
    assert np.array_equal(np.asarray(p["b"]["inner"]), np.arange(4))
    assert np.array_equal(np.asarray(o["mu"]), np.full((3, 2), 0.5))
    assert extra == {"tokens": 123}


def test_pytree_save_load_preserves_dtypes(tmp_path):
    tree = {"f16": np.ones(3, np.float16),
            "i8": np.arange(3, dtype=np.int8)}
    save_pytree(tree, tmp_path / "t")
    out = load_pytree(tree, tmp_path / "t")
    assert np.asarray(out["f16"]).dtype == np.float16
    assert np.asarray(out["i8"]).dtype == np.int8


# ---------------------------------------------------------------------------
# restore_latest skips incomplete / corrupt steps
# ---------------------------------------------------------------------------

def test_restore_latest_skips_incomplete_step(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _params(1.0), _opt())
    # a crashed save: directory exists but no DONE marker
    crashed = tmp_path / "step_00000002"
    (crashed / "params").mkdir(parents=True)
    assert mgr.steps() == [1]
    step, p, _, _ = mgr.restore_latest(_params(), _opt())
    assert step == 1
    assert np.asarray(p["w"])[0, 0] == 1.0


def test_restore_latest_skips_tmp_directory(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _params(1.0), _opt())
    # a save killed mid-write: .tmp staging dir never renamed
    tmp = tmp_path / "step_00000005.tmp"
    (tmp / "params").mkdir(parents=True)
    (tmp / "DONE").write_text("{}")
    assert mgr.latest_step() == 1


def test_restore_latest_none_when_empty(tmp_path):
    mgr = CheckpointManager(tmp_path)
    assert mgr.restore_latest(_params(), _opt()) is None


def test_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _params(float(s)), _opt())
    assert mgr.steps() == [3, 4]
    assert not (tmp_path / "step_00000001").exists()


# ---------------------------------------------------------------------------
# async save
# ---------------------------------------------------------------------------

def test_async_save_then_wait_is_restorable(tmp_path):
    mgr = CheckpointManager(tmp_path)
    params = _params(4.0)
    mgr.save(9, params, _opt(), blocking=False)
    mgr.wait()
    assert mgr.steps() == [9]
    _, p, _, _ = mgr.restore_latest(_params(), _opt())
    assert np.asarray(p["w"])[0, 0] == 4.0


def test_async_save_snapshots_before_return(tmp_path):
    """The device->host snapshot happens synchronously: mutating the live
    arrays after save(..., blocking=False) returns must not corrupt the
    checkpoint (the donate/overwrite pattern of a training loop)."""
    mgr = CheckpointManager(tmp_path)
    params = _params(1.0)
    mgr.save(1, params, _opt(), blocking=False)
    params["w"][:] = -999.0           # overwritten right after return
    mgr.wait()
    _, p, _, _ = mgr.restore_latest(_params(), _opt())
    assert np.asarray(p["w"])[0, 0] == 1.0


def test_async_save_snapshots_jax_arrays_too(tmp_path):
    """On the CPU backend device_get of a jax Array is a zero-copy view
    of the device buffer, so the snapshot must copy it as well — or a
    donated/overwritten buffer corrupts the in-flight async write."""
    import jax.numpy as jnp
    mgr = CheckpointManager(tmp_path)
    params = {"w": jnp.full((8,), 3.0, jnp.float32)}
    mgr.save(1, params, {}, blocking=False)
    # simulate donation: the device buffer gets reused immediately
    params["w"] = params["w"].at[:].set(-1.0)
    mgr.wait()
    _, p, _, _ = mgr.restore_latest({"w": np.zeros(8, np.float32)}, {})
    assert np.asarray(p["w"])[0] == 3.0


def test_second_save_waits_for_inflight_write(tmp_path):
    """save() joins the previous async writer before snapshotting, so
    checkpoints publish in order even under back-to-back async saves."""
    mgr = CheckpointManager(tmp_path)
    release = threading.Event()
    orig = save_pytree

    def slow_save(tree, directory):
        if directory.name == "params" and "00000001" in str(directory):
            release.wait(timeout=10)
        return orig(tree, directory)

    import repro.ckpt.manager as M
    M.save_pytree = slow_save
    try:
        mgr.save(1, _params(1.0), _opt(), blocking=False)
        t = threading.Thread(
            target=lambda: mgr.save(2, _params(2.0), _opt()))
        t.start()
        time.sleep(0.05)
        assert mgr.steps() == []          # save(2) parked behind save(1)
        release.set()
        t.join(timeout=10)
        assert mgr.steps() == [1, 2]
    finally:
        M.save_pytree = orig


def test_resave_same_step_overwrites(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, _params(1.0), _opt())
    mgr.save(5, _params(2.0), _opt())
    _, p, _, _ = mgr.restore_latest(_params(), _opt())
    assert np.asarray(p["w"])[0, 0] == 2.0
    assert mgr.steps() == [5]


def test_async_write_failure_reraised_at_wait(tmp_path, monkeypatch):
    """A persistent IO failure in the background writer must surface at
    the next synchronization point, not vanish in the daemon thread."""
    import repro.ckpt.manager as M

    def bad_save(tree, path):
        raise OSError("disk full")

    monkeypatch.setattr(M, "save_pytree", bad_save)
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _params(), _opt(), blocking=False)
    with pytest.raises(OSError, match="disk full"):
        mgr.wait()          # the write retried once, then propagated
    mgr.wait()              # failure is consumed: the next wait is clean
    assert mgr.steps() == []          # nothing half-published
