"""The S:Perf optimization implementations must be semantically equivalent
to their baselines (chunked attention, scatter_fast routing, dense GShard
dispatch, 2D resident sharding)."""

import dataclasses
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models import lm

pytestmark = pytest.mark.slow  # JAX-compile-heavy: excluded from the tier-1 default run

SRC = str(Path(__file__).resolve().parents[1] / "src")


def rand(k, s, dt=jnp.float32, scale=1.0):
    return (jax.random.normal(k, s, jnp.float32) * scale).astype(dt)


class TestChunkedAttention:
    @pytest.mark.parametrize("causal,window,chunk", [
        (True, None, 64), (False, None, 64), (True, 96, 64),
        (True, None, 33),                      # non-divisor chunk (pad path)
    ])
    def test_matches_naive(self, causal, window, chunk):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = rand(ks[0], (2, 128, 4, 32))
        k = rand(ks[1], (2, 128, 2, 32))
        v = rand(ks[2], (2, 128, 2, 32))
        got = L.sdpa_chunked(q, k, v, causal=causal, window=window,
                             chunk=chunk)
        want = L.sdpa(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5)

    def test_grad_matches_naive(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = rand(ks[0], (1, 64, 2, 16))
        k = rand(ks[1], (1, 64, 2, 16))
        v = rand(ks[2], (1, 64, 2, 16))

        g1 = jax.grad(lambda q: jnp.sum(
            L.sdpa_chunked(q, k, v, causal=True, chunk=16) ** 2))(q)
        g2 = jax.grad(lambda q: jnp.sum(
            L.sdpa(q, k, v, causal=True) ** 2))(q)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   atol=1e-4)

    def test_end_to_end_forward(self):
        cfg = get_config("qwen3_0_6b").with_reduced()
        cfgc = dataclasses.replace(cfg, attn_impl="chunked")
        p = lm.init_params(cfg, jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab)
        l1, _ = lm.forward(p, cfg, toks)
        l2, _ = lm.forward(p, cfgc, toks)
        np.testing.assert_allclose(np.asarray(l1, np.float32),
                                   np.asarray(l2, np.float32), atol=5e-2)


class TestMoEDispatch:
    def _setup(self):
        cfg = get_config("granite_moe_1b_a400m").with_reduced()
        p = L.init_moe(jax.random.key(0), cfg, jnp.bfloat16)
        x = rand(jax.random.PRNGKey(1), (2, 32, cfg.d_model), jnp.bfloat16)
        return cfg, p, x

    def test_scatter_fast_equals_scatter_exactly(self):
        """associative_scan routing must be bit-identical routing — same
        drops, same slots."""
        cfg, p, x = self._setup()
        cfgf = dataclasses.replace(cfg, moe_impl="scatter_fast")
        y1, a1 = L.moe_layer(p, cfg, x)
        y2, a2 = L.moe_layer(p, cfgf, x)
        np.testing.assert_array_equal(np.asarray(y1, np.float32),
                                      np.asarray(y2, np.float32))

    def test_dense_equals_scatter_when_no_drops(self):
        cfg, p, x = self._setup()
        cfgd = dataclasses.replace(cfg, moe_impl="dense")
        y1, _ = L.moe_layer(p, cfg, x, capacity_factor=4.0)
        y2, _ = L.moe_layer(p, cfgd, x, capacity_factor=4.0)
        np.testing.assert_allclose(np.asarray(y1, np.float32),
                                   np.asarray(y2, np.float32), atol=2e-2)

    def test_dense_grad_flows(self):
        cfg, p, x = self._setup()
        cfgd = dataclasses.replace(cfg, moe_impl="dense")

        def loss(p):
            y, aux = L.moe_layer(p, cfgd, x, capacity_factor=4.0)
            return jnp.sum(y.astype(jnp.float32) ** 2) + aux

        g = jax.grad(loss)(p)
        for leaf in jax.tree.leaves(g):
            assert np.isfinite(np.asarray(leaf, np.float32)).all()


class TestKVQuant:
    def test_int8_cache_decode_close_to_fp(self):
        cfg = get_config("qwen3_0_6b").with_reduced()
        cfgq = dataclasses.replace(cfg, kv_quant=True)
        p = lm.init_params(cfg, jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
        l1, c1 = lm.prefill(p, cfg, toks, max_seq=32)
        l2, c2 = lm.prefill(p, cfgq, toks, max_seq=32)
        assert c2["k"].dtype == jnp.int8
        assert c2["k_scale"].dtype == jnp.float16
        # decode 3 tokens with the SAME token stream through both caches:
        # this isolates cache fidelity from greedy-path divergence
        t = jnp.argmax(l1, -1).astype(jnp.int32)
        for _ in range(3):
            g1, c1 = lm.decode_step(p, cfg, t, c1)
            g2, c2 = lm.decode_step(p, cfgq, t, c2)
            rel = float(jnp.linalg.norm(
                g1.astype(jnp.float32) - g2.astype(jnp.float32)) /
                jnp.linalg.norm(g1.astype(jnp.float32)))
            assert rel < 0.05, rel
            t = jnp.argmax(g1, -1).astype(jnp.int32)

    def test_quantize_roundtrip(self):
        t = rand(jax.random.PRNGKey(0), (2, 8, 4, 32), jnp.bfloat16)
        q, s = L.quantize_kv(t)
        back = L.dequantize_kv(q, s, jnp.float32)
        rel = float(jnp.linalg.norm(back - np.asarray(t, np.float32)) /
                    jnp.linalg.norm(np.asarray(t, np.float32)))
        assert rel < 0.01
        # cache footprint halves (+ small scale overhead)
        assert q.dtype == jnp.int8 and s.dtype == jnp.float16


class TestFlashDecodeWiring:
    def test_kernel_decode_matches_naive(self):
        cfg = get_config("qwen3_0_6b").with_reduced()
        cfgk = dataclasses.replace(cfg, attn_impl="kernel")
        p = lm.init_params(cfg, jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
        l1, c1 = lm.prefill(p, cfg, toks, max_seq=256)
        l2, c2 = lm.prefill(p, cfgk, toks, max_seq=256)
        t = jnp.argmax(l1, -1).astype(jnp.int32)
        for _ in range(2):
            g1, c1 = lm.decode_step(p, cfg, t, c1)
            g2, c2 = lm.decode_step(p, cfgk, t, c2)
            rel = float(jnp.linalg.norm(
                g1.astype(jnp.float32) - g2.astype(jnp.float32)) /
                jnp.linalg.norm(g1.astype(jnp.float32)))
            assert rel < 0.02, rel
            t = jnp.argmax(g1, -1).astype(jnp.int32)


class TestTwoDPolicy:
    def test_resident_sharding_lowers(self):
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = \
                "--xla_force_host_platform_device_count=8"
            import dataclasses, jax
            from repro.configs import get_config
            from repro.distributed.sharding import ShardingPolicy
            from repro.launch.steps import input_specs
            from repro.models.config import InputShape

            cfg = get_config("granite-moe-1b-a400m").with_reduced()
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            pol = ShardingPolicy(two_d=True, fsdp=False, batch_axes=())
            shape = InputShape("dec", 128, 8, "decode")
            spec = input_specs(cfg, shape, mesh, pol=pol)
            with mesh:
                c = jax.jit(spec["fn"], in_shardings=spec["in_shardings"],
                            out_shardings=spec["out_shardings"],
                            donate_argnums=spec["donate_argnums"]).lower(
                                *spec["args"]).compile()
            assert c.cost_analysis()["flops"] > 0
            print("SUBPROCESS_OK")
        """)
        r = subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, timeout=600,
                           env={"PYTHONPATH": SRC,
                                "PATH": "/usr/bin:/bin"})
        assert r.returncode == 0, r.stderr[-2000:]
        assert "SUBPROCESS_OK" in r.stdout
