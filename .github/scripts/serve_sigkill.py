"""CI crash-recovery drill: SIGKILL the serving driver mid-stream.

Starts ``repro.launch.serve --journal``, waits until the write-ahead
journal shows real decode progress, delivers SIGKILL (no cleanup, no
signal handler — the preemption guard never runs), then re-runs the
identical command.  The restarted process must drain the journal and
answer every request exactly once: retired rids straight from the
journal, in-flight rids resumed at their last journaled token.

Exit code 0 only if the kill really landed mid-stream (requests were
in flight) and the restart retired every request.
"""

import json
import os
import re
import signal
import subprocess
import sys
import tempfile
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[2]
sys.path.insert(0, str(ROOT / "src"))

from repro.serve import ServeJournal  # noqa: E402

N_REQS = 8


def main() -> int:
    env = {**os.environ, "PYTHONPATH": str(ROOT / "src"),
           "JAX_PLATFORMS": "cpu"}
    work = Path(tempfile.mkdtemp(prefix="serve_sigkill_"))
    jp = work / "journal.jsonl"
    cmd = [sys.executable, "-m", "repro.launch.serve", "--per-slot",
           "--requests", str(N_REQS), "--max-new", "24", "--slots", "2",
           "--journal", str(jp)]

    print("[drill] starting victim:", " ".join(cmd))
    p = subprocess.Popen(cmd, cwd=ROOT, env=env,
                         stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
                         text=True)
    deadline = time.time() + 600
    try:
        while time.time() < deadline:
            if jp.exists():
                toks = sum(1 for line in open(jp) if '"t":"tok"' in line)
                if toks >= 8:
                    break
            if p.poll() is not None:
                print(p.communicate()[0][-4000:])
                print("[drill] FAIL: victim finished before the kill")
                return 1
            time.sleep(0.05)
        else:
            print("[drill] FAIL: no journal progress before deadline")
            return 1
        p.send_signal(signal.SIGKILL)
        p.wait(timeout=60)
    finally:
        if p.poll() is None:
            p.kill()
    print(f"[drill] SIGKILL delivered (exit {p.returncode})")

    completed, inflight = ServeJournal.replay(jp)
    print(f"[drill] journal at kill: {len(completed)} retired, "
          f"{len(inflight)} in-flight")
    if not inflight:
        print("[drill] FAIL: kill landed after all requests finished")
        return 1

    print("[drill] restarting with the same command + journal")
    r = subprocess.run(cmd, cwd=ROOT, env=env, capture_output=True,
                       text=True, timeout=600)
    sys.stdout.write(r.stdout[-4000:])
    if r.returncode != 0:
        sys.stdout.write(r.stderr[-4000:])
        print("[drill] FAIL: restarted driver exited", r.returncode)
        return 1

    completed, inflight = ServeJournal.replay(jp)
    if inflight or sorted(completed) != list(range(N_REQS)):
        print(f"[drill] FAIL: journal not drained "
              f"(retired={sorted(completed)}, inflight={sorted(inflight)})")
        return 1
    # the restarted driver must have answered each rid exactly once
    answered = re.findall(r"\[serve\] req (\d+):", r.stdout)
    if sorted(int(a) for a in answered) != list(range(N_REQS)):
        print(f"[drill] FAIL: answered rids {answered}")
        return 1
    print("[drill] OK: exactly-once drain after SIGKILL")
    return 0


if __name__ == "__main__":
    sys.exit(main())
