"""Serving decode throughput: per-slot seed path vs the batched fast path.

The third leg of the perf trajectory (after ``BENCH_sim_time.json`` for
channel throughput and ``BENCH_codegen_time.json`` for compile time): how
many tokens per second the serving engine decodes, per slot count, under

  per_slot   the seed decode loop — one jitted call per live slot per
             token and a host ``np.argmax`` round-trip each;
  batched    the packed-slot path — ONE jitted step per iteration for the
             whole slot array (ragged flash-decode attention, on-device
             sampling, a single [slots] token fetch per step).

The per-slot path's cost grows linearly with slot count (dispatch + host
sync per slot), the batched path's stays ~flat — the whole point of
packing.  Acceptance bar (CI gate): batched >= 3x per_slot tokens/sec at
8 slots.  Both engines warm up first so XLA compiles are excluded; the
timed run re-serves a fresh request list through an already-warm engine.

Results persist to ``BENCH_serve_time.json`` at the repo root.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

try:
    from benchmarks._bench import bench_path, write_bench
except ImportError:                     # script mode: python benchmarks/...
    from _bench import bench_path, write_bench

BENCH_JSON = bench_path("serve_time")

GATE_SLOTS = 8
GATE_SPEEDUP = 3.0


def _make_requests(n: int, max_new: int, vocab: int, seed: int = 0) -> list:
    """Random token ids but a *deterministic* prompt-length cycle: the
    per-slot path jit-compiles prefill per exact length, so keeping the
    length set fixed ensures the warm run pays every compile and the timed
    runs measure decode throughput only — for both variants."""
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    lengths = (4, 7, 9, 12, 14, 16)
    return [Request(rid=i,
                    prompt=rng.integers(
                        0, vocab, lengths[i % len(lengths)]).tolist(),
                    max_new=max_new)
            for i in range(n)]


def _build(cfg, params, variant: str, slots: int, max_seq: int, cc):
    from repro.models import lm
    from repro.serve import ServeConfig, ServingEngine
    scfg = ServeConfig(batch_slots=slots, max_seq=max_seq)
    if variant == "batched":
        adapter = lm.serving_adapter(params, cfg, max_seq=max_seq)
        eng = ServingEngine(scfg, batched=adapter)
        eng.warmup(cache=cc)
        return eng

    @jax.jit
    def prefill_fn(tokens):
        return lm.prefill(params, cfg, tokens, max_seq=max_seq)

    @jax.jit
    def decode_fn(token, cache):
        return lm.decode_step(params, cfg, token, cache)

    return ServingEngine(scfg, prefill_fn, decode_fn)


def measure(slot_counts=(1, 4, 8), requests_per_slot: int = 2,
            max_new: int = 40, max_seq: int = 64, repeats: int = 2) -> dict:
    from repro.configs import get_config
    from repro.core.compile_cache import CompileCache
    from repro.models import lm
    from repro.serve import serve_requests

    # a notch above the test-size reduction: per-slot cost is
    # slots x (dispatch + compute) while the batched step vectorizes the
    # compute across slots, so a non-trivial layer stack makes the
    # comparison reflect real serving arithmetic, not just dispatch.
    cfg = get_config("qwen3-0.6b").with_reduced(
        n_layers=4, d_model=128, d_ff=256)
    params = lm.init_params(cfg, jax.random.key(0))
    cc = CompileCache(disk=False)        # isolate the benchmark from $HOME

    rows = []
    for slots in slot_counts:
        n_req = max(slots * requests_per_slot, 2)
        for variant in ("per_slot", "batched"):
            eng = _build(cfg, params, variant, slots, max_seq, cc)
            # warm run: pays every XLA compile/dispatch-path setup
            serve_requests(eng, _make_requests(n_req, max_new, cfg.vocab))
            best = None
            for rep in range(repeats):
                reqs = _make_requests(n_req, max_new, cfg.vocab,
                                      seed=rep + 1)
                t0 = time.perf_counter()
                res = serve_requests(eng, reqs)
                wall = time.perf_counter() - t0
                n_new = sum(len(v) for v in res.values())
                assert len(res) == n_req, (variant, slots, len(res))
                if best is None or wall < best[0]:
                    best = (wall, n_new)
            wall, n_new = best
            rows.append({
                "variant": variant, "slots": slots,
                "requests": n_req, "new_tokens": n_new,
                "tokens_per_sec": round(n_new / wall, 1),
                "wall_s": round(wall, 4),
            })

    def tps(variant, slots):
        for r in rows:
            if r["variant"] == variant and r["slots"] == slots:
                return r["tokens_per_sec"]
        return None

    speedups = {s: round(tps("batched", s) / tps("per_slot", s), 2)
                for s in slot_counts}
    gate_slots = GATE_SLOTS if GATE_SLOTS in slot_counts \
        else max(slot_counts)
    out = {
        "benchmark": "serve_time",
        "config": {"arch": cfg.name, "max_seq": max_seq,
                   "max_new": max_new, "slot_counts": list(slot_counts),
                   "requests_per_slot": requests_per_slot,
                   "repeats": repeats},
        "rows": rows,
        "batched_speedup_by_slots": speedups,
        "gate": {"slots": gate_slots, "bar": GATE_SPEEDUP,
                 "speedup": speedups[gate_slots],
                 "serve_regression": speedups[gate_slots] < GATE_SPEEDUP},
    }
    return out


def print_report(res: dict) -> None:
    print(f"{'variant':<10} {'slots':>5} {'tokens/s':>10} {'wall_ms':>9}")
    for r in res["rows"]:
        print(f"{r['variant']:<10} {r['slots']:>5} "
              f"{r['tokens_per_sec']:>10.0f} {r['wall_s']*1e3:>9.1f}")
    for s, x in res["batched_speedup_by_slots"].items():
        print(f"batched vs per-slot @ {s} slots: {x}x")
    g = res["gate"]
    status = "FAIL" if g["serve_regression"] else "ok"
    print(f"gate: batched >= {g['bar']}x at {g['slots']} slots -> "
          f"{g['speedup']}x [{status}]")


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer requests/tokens, single repeat")
    args = ap.parse_args(argv)

    if args.quick:
        res = measure(slot_counts=(1, 8), requests_per_slot=1,
                      max_new=32, repeats=1)
    else:
        res = measure()
    print_report(res)
    write_bench("serve_time", res)
    print(f"wrote {BENCH_JSON}")
    return res


if __name__ == "__main__":
    import sys
    sys.exit(1 if main()["gate"]["serve_regression"] else 0)
