"""Serving decode throughput: per-slot seed path vs the batched fast path.

The third leg of the perf trajectory (after ``BENCH_sim_time.json`` for
channel throughput and ``BENCH_codegen_time.json`` for compile time): how
many tokens per second the serving engine decodes, per slot count, under

  per_slot   the seed decode loop — one jitted call per live slot per
             token and a host ``np.argmax`` round-trip each;
  batched    the packed-slot path — ONE jitted step per iteration for the
             whole slot array (ragged flash-decode attention, on-device
             sampling, a single [slots] token fetch per step).

The per-slot path's cost grows linearly with slot count (dispatch + host
sync per slot), the batched path's stays ~flat — the whole point of
packing.  Acceptance bar (CI gate): batched >= 3x per_slot tokens/sec at
8 slots.  Both engines warm up first so XLA compiles are excluded; the
timed run re-serves a fresh request list through an already-warm engine.

The **overload** section (PR 8) measures goodput vs offered load under
seeded open-loop traffic (``repro/serve/traffic.py``): a closed-loop run
fixes the engine's capacity, then the same traffic seed is replayed at
1x and 2x that capacity with admission control + load shedding on, and
at 2x with shedding off (the collapse arm, kept as evidence — the
deadline-violation assertion lives in ``tests/test_overload.py``).
Gates are *relative ratios within one run* so they hold across machines:
at 2x offered load with shedding, goodput must stay within 20% of the
1x arm and p99 TTFT of admitted requests must stay under the SLO.

Results persist to ``BENCH_serve_time.json`` at the repo root.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

try:
    from benchmarks._bench import bench_path, write_bench
except ImportError:                     # script mode: python benchmarks/...
    from _bench import bench_path, write_bench

BENCH_JSON = bench_path("serve_time")

GATE_SLOTS = 8
GATE_SPEEDUP = 3.0
# overload gates (relative, within one run): 2x-load goodput must stay
# within 20% of the 1x arm, and p99 TTFT of admitted requests must stay
# under the per-request SLO
GATE_OVERLOAD_GOODPUT = 0.8
OVERLOAD_DEADLINE_S = 0.5


def _make_requests(n: int, max_new: int, vocab: int, seed: int = 0) -> list:
    """Random token ids but a *deterministic* prompt-length cycle: the
    per-slot path jit-compiles prefill per exact length, so keeping the
    length set fixed ensures the warm run pays every compile and the timed
    runs measure decode throughput only — for both variants."""
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    lengths = (4, 7, 9, 12, 14, 16)
    return [Request(rid=i,
                    prompt=rng.integers(
                        0, vocab, lengths[i % len(lengths)]).tolist(),
                    max_new=max_new)
            for i in range(n)]


def _build(cfg, params, variant: str, slots: int, max_seq: int, cc):
    from repro.models import lm
    from repro.serve import ServeConfig, ServingEngine
    scfg = ServeConfig(batch_slots=slots, max_seq=max_seq)
    if variant == "batched":
        adapter = lm.serving_adapter(params, cfg, max_seq=max_seq)
        eng = ServingEngine(scfg, batched=adapter)
        eng.warmup(cache=cc)
        return eng

    @jax.jit
    def prefill_fn(tokens):
        return lm.prefill(params, cfg, tokens, max_seq=max_seq)

    @jax.jit
    def decode_fn(token, cache):
        return lm.decode_step(params, cfg, token, cache)

    return ServingEngine(scfg, prefill_fn, decode_fn)


def measure(slot_counts=(1, 4, 8), requests_per_slot: int = 2,
            max_new: int = 40, max_seq: int = 64, repeats: int = 2) -> dict:
    from repro.configs import get_config
    from repro.core.compile_cache import CompileCache
    from repro.models import lm
    from repro.serve import serve_requests

    # a notch above the test-size reduction: per-slot cost is
    # slots x (dispatch + compute) while the batched step vectorizes the
    # compute across slots, so a non-trivial layer stack makes the
    # comparison reflect real serving arithmetic, not just dispatch.
    cfg = get_config("qwen3-0.6b").with_reduced(
        n_layers=4, d_model=128, d_ff=256)
    params = lm.init_params(cfg, jax.random.key(0))
    cc = CompileCache(disk=False)        # isolate the benchmark from $HOME

    rows = []
    for slots in slot_counts:
        n_req = max(slots * requests_per_slot, 2)
        for variant in ("per_slot", "batched"):
            eng = _build(cfg, params, variant, slots, max_seq, cc)
            # warm run: pays every XLA compile/dispatch-path setup
            serve_requests(eng, _make_requests(n_req, max_new, cfg.vocab))
            best = None
            for rep in range(repeats):
                reqs = _make_requests(n_req, max_new, cfg.vocab,
                                      seed=rep + 1)
                t0 = time.perf_counter()
                res = serve_requests(eng, reqs)
                wall = time.perf_counter() - t0
                n_new = sum(len(v) for v in res.values())
                assert len(res) == n_req, (variant, slots, len(res))
                if best is None or wall < best[0]:
                    best = (wall, n_new)
            wall, n_new = best
            rows.append({
                "variant": variant, "slots": slots,
                "requests": n_req, "new_tokens": n_new,
                "tokens_per_sec": round(n_new / wall, 1),
                "wall_s": round(wall, 4),
            })

    def tps(variant, slots):
        for r in rows:
            if r["variant"] == variant and r["slots"] == slots:
                return r["tokens_per_sec"]
        return None

    speedups = {s: round(tps("batched", s) / tps("per_slot", s), 2)
                for s in slot_counts}
    gate_slots = GATE_SLOTS if GATE_SLOTS in slot_counts \
        else max(slot_counts)
    out = {
        "benchmark": "serve_time",
        "config": {"arch": cfg.name, "max_seq": max_seq,
                   "max_new": max_new, "slot_counts": list(slot_counts),
                   "requests_per_slot": requests_per_slot,
                   "repeats": repeats},
        "rows": rows,
        "batched_speedup_by_slots": speedups,
        "gate": {"slots": gate_slots, "bar": GATE_SPEEDUP,
                 "speedup": speedups[gate_slots],
                 "serve_regression": speedups[gate_slots] < GATE_SPEEDUP},
    }
    return out


def measure_overload(duration: float = 1.5, slots: int = 4,
                     max_new: int = 16, max_seq: int = 64,
                     seed: int = 0) -> dict:
    """Goodput vs offered load under seeded open-loop traffic.

    Capacity is measured closed-loop first, then the offered rate is set
    relative to it — so the 1x/2x arms mean the same thing on any
    machine and the gates can be pure ratios.  All three traffic arms
    share one traffic seed: the 2x arms are the *same* arrival process
    densified, not a different workload.
    """
    from repro.configs import get_config
    from repro.core.compile_cache import CompileCache
    from repro.models import lm
    from repro.serve import (AdmissionConfig, AdmissionController,
                             ServeConfig, ServeMetrics, ServingEngine,
                             make_trace, serve_requests, trace_digest,
                             uniform_mix)

    cfg = get_config("qwen3-0.6b").with_reduced(
        n_layers=4, d_model=128, d_ff=256)
    params = lm.init_params(cfg, jax.random.key(0))
    cc = CompileCache(disk=False)
    deadline_s = OVERLOAD_DEADLINE_S

    def build(**kw):
        scfg = ServeConfig(batch_slots=slots, max_seq=max_seq)
        adapter = lm.serving_adapter(params, cfg, max_seq=max_seq)
        eng = ServingEngine(scfg, batched=adapter, **kw)
        eng.warmup(cache=cc)
        return eng

    # -- closed-loop capacity: saturate the slots, no pacing --------------
    eng = build()
    serve_requests(eng, _make_requests(slots * 4, max_new, cfg.vocab))
    t0 = time.perf_counter()
    res = serve_requests(eng, _make_requests(slots * 4, max_new, cfg.vocab,
                                             seed=1))
    cap_wall = time.perf_counter() - t0
    cap_tok_s = sum(len(v) for v in res.values()) / cap_wall
    # offered "1x" = 75% of measured capacity: the closed-loop figure
    # undershoots open-loop throughput (it serializes waves), so 0.75x
    # keeps the 1x arm stable while 2x is genuinely supersaturated
    base_req_s = 0.75 * cap_tok_s / max_new

    arms = {}
    for label, scale, shed in (("load_1x", 1.0, True),
                               ("load_2x", 2.0, True),
                               ("load_2x_noshed", 2.0, False)):
        tenants = uniform_mix(2, rate=base_req_s / 2,
                              deadline_s=deadline_s,
                              max_new=(max_new, max_new))
        trace = make_trace(tenants, duration, seed=seed, vocab=cfg.vocab,
                           scale=scale)
        metrics = ServeMetrics()
        ctrl = None
        if shed:
            ctrl = AdmissionController(
                AdmissionConfig(shed_policy="reject-new",
                                queue_limit=slots * 8,
                                est_token_s=1.0 / cap_tok_s),
                metrics=metrics)
            ctrl.register_tenants(tenants)
        eng = build(admission=ctrl, metrics=metrics, pace="wall")
        t0 = time.perf_counter()
        res = serve_requests(eng, trace, sim_engine="thread",
                             watchdog_s=120)
        wall = time.perf_counter() - t0
        # open-loop invariants: every offered request answered, and
        # offered == admitted + shed per tenant
        assert len(res) == len(trace), (label, len(res), len(trace))
        metrics.check_accounting()
        summ = metrics.summary(wall_s=wall)
        summ["trace_digest"] = trace_digest(trace)[:16]
        summ["offered_req_s"] = round(base_req_s * scale, 2)
        arms[label] = summ

    g1, g2 = (arms["load_1x"]["goodput_tok_s"] or 0.0,
              arms["load_2x"]["goodput_tok_s"] or 0.0)
    ratio = round(g2 / g1, 3) if g1 else None
    p99 = arms["load_2x"]["ttft_p99_s"]
    return {
        "capacity_tok_s": round(cap_tok_s, 1),
        "deadline_s": deadline_s,
        "arms": arms,
        "goodput_2x_over_1x": ratio,
        "gate": {
            "goodput_bar": GATE_OVERLOAD_GOODPUT,
            "goodput_2x_over_1x": ratio,
            "ttft_p99_2x_s": p99,
            "ttft_p99_bound_s": deadline_s,
            "overload_regression": (
                ratio is None or ratio < GATE_OVERLOAD_GOODPUT
                or (p99 is not None and p99 > deadline_s)),
        },
    }


def print_report(res: dict) -> None:
    print(f"{'variant':<10} {'slots':>5} {'tokens/s':>10} {'wall_ms':>9}")
    for r in res["rows"]:
        print(f"{r['variant']:<10} {r['slots']:>5} "
              f"{r['tokens_per_sec']:>10.0f} {r['wall_s']*1e3:>9.1f}")
    for s, x in res["batched_speedup_by_slots"].items():
        print(f"batched vs per-slot @ {s} slots: {x}x")
    g = res["gate"]
    status = "FAIL" if g["serve_regression"] else "ok"
    print(f"gate: batched >= {g['bar']}x at {g['slots']} slots -> "
          f"{g['speedup']}x [{status}]")

    ov = res.get("overload")
    if not ov:
        return
    print(f"\noverload (capacity {ov['capacity_tok_s']:.0f} tok/s, "
          f"deadline {ov['deadline_s']*1e3:.0f}ms):")
    print(f"{'arm':<16} {'offered':>7} {'admit':>6} {'shed':>5} "
          f"{'viol':>5} {'goodput':>8} {'p99 ttft':>9}")
    for label, a in ov["arms"].items():
        p99 = a["ttft_p99_s"]
        print(f"{label:<16} {a['offered']:>7} {a['admitted']:>6} "
              f"{a['shed']:>5} {a['deadline_violations']:>5} "
              f"{a['goodput_tok_s'] or 0:>8.1f} "
              f"{'-' if p99 is None else f'{p99*1e3:.0f}ms':>9}")
    og = ov["gate"]
    status = "FAIL" if og["overload_regression"] else "ok"
    p99g = og["ttft_p99_2x_s"]
    p99s = "-" if p99g is None else f"{p99g*1e3:.0f}ms"
    print(f"gate: 2x/1x goodput >= {og['goodput_bar']} -> "
          f"{og['goodput_2x_over_1x']}, p99 ttft <= "
          f"{og['ttft_p99_bound_s']*1e3:.0f}ms -> {p99s} [{status}]")


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer requests/tokens, single repeat")
    args = ap.parse_args(argv)

    if args.quick:
        res = measure(slot_counts=(1, 8), requests_per_slot=1,
                      max_new=32, repeats=1)
        res["overload"] = measure_overload(duration=1.0)
    else:
        res = measure()
        res["overload"] = measure_overload()
    res["gate"]["overload_regression"] = \
        res["overload"]["gate"]["overload_regression"]
    print_report(res)
    write_bench("serve_time", res)
    print(f"wrote {BENCH_JSON}")
    return res


if __name__ == "__main__":
    import sys
    _g = main()["gate"]
    sys.exit(1 if (_g["serve_regression"] or _g["overload_regression"])
             else 0)
