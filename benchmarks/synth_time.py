"""Synthesized-vs-simulated throughput: the whole-graph XLA program
against its own simulation twin (emits ``BENCH_synth_time.json``).

A deep streaming pipeline — Source -> N x Relay -> Sink, moving a fixed
token volume in bursts over typed fixed-capacity channels — is built once
in step-function form and run two ways:

  coroutine_twin   the StepTask bodies executed by the coroutine engine
                   (run-to-block scheduling, real blocking streams) — the
                   correctness side of the paper's Fig. 2 cycle;
  compiled         the same graph lowered by ``CompiledEngine`` into one
                   jitted program (ring buffers + guarded steps inside a
                   ``lax.while_loop``), through the persistent compile
                   cache.

Acceptance gate: compiled tokens/sec >= 10x the coroutine twin.  The
compiled row is measured hot (the first run pays the XLA compile and
primes the cache; a second process would pay nothing — subprocess-tested
in tests/test_synth.py).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

try:
    from benchmarks._bench import bench_path, write_bench
except ImportError:                     # script mode: python benchmarks/...
    from _bench import bench_path, write_bench

BENCH_JSON = bench_path("synth_time")

GATE_X = 10.0


def build_pipeline(n_tokens: int, stages: int, burst: int, capacity: int):
    """Step-form Source -> stages x Relay -> Sink; the sink writes every
    token into a result mmap (verifiable end to end)."""
    import jax.numpy as jnp

    import repro
    from repro import StepTask, channel, mmap

    assert n_tokens % burst == 0
    fires = n_tokens // burst

    def source_step(k, out):
        out.write_burst(k * burst + jnp.arange(burst, dtype=jnp.int32))
        return k + 1

    def relay_step(state, inp, out):
        out.write_burst(inp.read_burst(burst))
        return state

    def sink_step(k, inp, res):
        res.write_burst(k * burst, inp.read_burst(burst))
        return k + 1

    Source = StepTask(source_step, steps=fires, init=jnp.int32(0),
                      name="Source")
    Relay = StepTask(relay_step, steps=fires, name="Relay")
    Sink = StepTask(sink_step, steps=fires, init=jnp.int32(0), name="Sink")

    buf = np.zeros(n_tokens, np.int32)
    res = mmap(buf, "res")

    def Top(res):
        chans = [channel(capacity, f"c{i}", dtype=np.int32, shape=())
                 for i in range(stages + 1)]
        t = repro.task().invoke(Source, chans[0])
        for s in range(stages):
            t = t.invoke(Relay, chans[s], chans[s + 1], name=f"Relay{s}")
        t.invoke(Sink, chans[stages], res)

    return Top, (res,), buf


def measure(n_tokens: int, stages: int, burst: int, capacity: int,
            repeats: int) -> dict:
    import repro

    hops = n_tokens * (stages + 1)
    rows = []

    # -- coroutine twin ------------------------------------------------------
    best = None
    switches = None
    for _ in range(repeats):
        top, args, buf = build_pipeline(n_tokens, stages, burst, capacity)
        rep = repro.ENGINES["coroutine"]().run(top, *args)
        assert rep.ok, rep.error
        assert np.array_equal(buf, np.arange(n_tokens)), "twin corrupted"
        if best is None or rep.wall_s < best:
            best, switches = rep.wall_s, rep.switches
    rows.append({"variant": "coroutine_twin",
                 "tokens_per_sec": round(hops / best, 1),
                 "switches": switches, "wall_s": round(best, 6)})

    # -- compiled ------------------------------------------------------------
    # first run pays the XLA compile (and primes the persistent cache);
    # measured rows run hot, like any serving path after warmup
    top, args, buf = build_pipeline(n_tokens, stages, burst, capacity)
    eng = repro.ENGINES["compiled"]()
    rep = eng.run(top, *args)
    assert rep.ok, rep.error
    cold_source = eng.compile_source
    best = None
    sweeps = None
    for _ in range(repeats):
        top, args, buf = build_pipeline(n_tokens, stages, burst, capacity)
        eng = repro.ENGINES["compiled"]()
        t0 = time.perf_counter()
        rep = eng.run(top, *args)
        wall = time.perf_counter() - t0
        assert rep.ok, rep.error
        assert np.array_equal(buf, np.arange(n_tokens)), "synth corrupted"
        assert eng.compile_source in ("memory", "disk"), eng.compile_source
        if best is None or wall < best:
            best, sweeps = wall, eng.n_sweeps
    rows.append({"variant": "compiled",
                 "tokens_per_sec": round(hops / best, 1),
                 "sweeps": sweeps, "wall_s": round(best, 6),
                 "cold_source": cold_source})

    speedup = round(rows[1]["tokens_per_sec"] / rows[0]["tokens_per_sec"], 2)
    return {
        "config": {"n_tokens": n_tokens, "stages": stages, "burst": burst,
                   "capacity": capacity, "repeats": repeats,
                   "hops": hops},
        "rows": rows,
        "compiled_speedup_vs_twin": speedup,
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: smaller token volume, single repeat")
    args = ap.parse_args(argv)

    if args.quick:
        out = measure(n_tokens=4096, stages=8, burst=64, capacity=64,
                      repeats=1)
    else:
        out = measure(n_tokens=16384, stages=8, burst=64, capacity=64,
                      repeats=2)

    cfg = out["config"]
    print(f"pipeline: {cfg['stages']} stages x {cfg['n_tokens']} tokens, "
          f"burst={cfg['burst']}, capacity={cfg['capacity']}")
    print(f"{'variant':<16} {'tokens/s':>14} {'wall_ms':>9}")
    for r in out["rows"]:
        print(f"{r['variant']:<16} {r['tokens_per_sec']:>14.0f} "
              f"{r['wall_s']*1e3:>9.1f}")
    print(f"compiled vs coroutine twin: "
          f"{out['compiled_speedup_vs_twin']}x (gate: >= {GATE_X}x)")

    out["gate"] = {"required_x": GATE_X,
                   "synth_regression":
                       out["compiled_speedup_vs_twin"] < GATE_X}
    write_bench("synth_time", out)
    print(f"wrote {BENCH_JSON}")
    if out["gate"]["synth_regression"]:
        print(f"SYNTH THROUGHPUT REGRESSION: "
              f"{out['compiled_speedup_vs_twin']}x < required {GATE_X}x")
    return out


if __name__ == "__main__":
    res = main()
    raise SystemExit(1 if res["gate"]["synth_regression"] else 0)
