"""Synthesized-vs-simulated throughput: the whole-graph XLA program
against its own simulation twin (emits ``BENCH_synth_time.json``).

A deep streaming pipeline — Source -> N x Relay -> Sink, moving a fixed
token volume in bursts over typed fixed-capacity channels — is built once
in step-function form and run two ways:

  coroutine_twin   the StepTask bodies executed by the coroutine engine
                   (run-to-block scheduling, real blocking streams) — the
                   correctness side of the paper's Fig. 2 cycle;
  compiled         the same graph lowered by ``CompiledEngine`` into one
                   jitted program (ring buffers + guarded steps inside a
                   ``lax.while_loop``), through the persistent compile
                   cache.

Acceptance gate: compiled tokens/sec >= 10x the coroutine twin.  The
compiled row is measured hot (the first run pays the XLA compile and
primes the cache; a second process would pay nothing — subprocess-tested
in tests/test_synth.py).

Two further sections ride on the same record:

  pallas_interconnect   the identical pipeline lowered once with the XLA
                        reference interconnect and once with the Pallas
                        ring/guard kernels ("pallas" on a TPU backend,
                        "interpret" elsewhere).  Gate: kernels >= 1.0x
                        the XLA path — enforced only on a real TPU; off-
                        TPU the ratio is recorded with the waiver reason
                        (the interpreter emulates, it doesn't accelerate).
  async_depth           a read-port fetch loop against a high-latency
                        memory (async_mmap lowered to the compiled
                        latency queue), outstanding depth 1 vs 4.  Gate:
                        depth-4 tokens/sec >= depth-1 (the issue-ahead
                        window must hide round-trips, paper S3.1.2).
  partition             the wide systolic gemm floorplanned across 1/2/4
                        mesh devices (cut channels -> ppermute
                        interconnect).  Two relative gates: the measured
                        4-device tokens/sec must be >= 1.5x 1-device
                        when the devices are real (waived on forced
                        host-platform devices sharing fewer physical
                        cores — emulated parallelism cannot move wall
                        clock), and the floorplanner's own objective
                        must *predict* >= 1.5x at 4 devices (enforced
                        everywhere 4 devices are visible: it is a
                        deterministic property of the placement, not of
                        the machine).
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np

try:
    from benchmarks._bench import bench_path, write_bench
except ImportError:                     # script mode: python benchmarks/...
    from _bench import bench_path, write_bench

BENCH_JSON = bench_path("synth_time")

GATE_X = 10.0
PARTITION_GATE_X = 1.5


def build_pipeline(n_tokens: int, stages: int, burst: int, capacity: int):
    """Step-form Source -> stages x Relay -> Sink; the sink writes every
    token into a result mmap (verifiable end to end)."""
    import jax.numpy as jnp

    import repro
    from repro import StepTask, channel, mmap

    assert n_tokens % burst == 0
    fires = n_tokens // burst

    def source_step(k, out):
        out.write_burst(k * burst + jnp.arange(burst, dtype=jnp.int32))
        return k + 1

    def relay_step(state, inp, out):
        out.write_burst(inp.read_burst(burst))
        return state

    def sink_step(k, inp, res):
        res.write_burst(k * burst, inp.read_burst(burst))
        return k + 1

    Source = StepTask(source_step, steps=fires, init=jnp.int32(0),
                      name="Source")
    Relay = StepTask(relay_step, steps=fires, name="Relay")
    Sink = StepTask(sink_step, steps=fires, init=jnp.int32(0), name="Sink")

    buf = np.zeros(n_tokens, np.int32)
    res = mmap(buf, "res")

    def Top(res):
        chans = [channel(capacity, f"c{i}", dtype=np.int32, shape=())
                 for i in range(stages + 1)]
        t = repro.task().invoke(Source, chans[0])
        for s in range(stages):
            t = t.invoke(Relay, chans[s], chans[s + 1], name=f"Relay{s}")
        t.invoke(Sink, chans[stages], res)

    return Top, (res,), buf


def measure(n_tokens: int, stages: int, burst: int, capacity: int,
            repeats: int) -> dict:
    import repro

    hops = n_tokens * (stages + 1)
    rows = []

    # -- coroutine twin ------------------------------------------------------
    best = None
    switches = None
    for _ in range(repeats):
        top, args, buf = build_pipeline(n_tokens, stages, burst, capacity)
        rep = repro.ENGINES["coroutine"]().run(top, *args)
        assert rep.ok, rep.error
        assert np.array_equal(buf, np.arange(n_tokens)), "twin corrupted"
        if best is None or rep.wall_s < best:
            best, switches = rep.wall_s, rep.switches
    rows.append({"variant": "coroutine_twin",
                 "tokens_per_sec": round(hops / best, 1),
                 "switches": switches, "wall_s": round(best, 6)})

    # -- compiled ------------------------------------------------------------
    # first run pays the XLA compile (and primes the persistent cache);
    # measured rows run hot, like any serving path after warmup
    top, args, buf = build_pipeline(n_tokens, stages, burst, capacity)
    eng = repro.ENGINES["compiled"]()
    rep = eng.run(top, *args)
    assert rep.ok, rep.error
    cold_source = eng.compile_source
    best = None
    sweeps = None
    for _ in range(repeats):
        top, args, buf = build_pipeline(n_tokens, stages, burst, capacity)
        eng = repro.ENGINES["compiled"]()
        t0 = time.perf_counter()
        rep = eng.run(top, *args)
        wall = time.perf_counter() - t0
        assert rep.ok, rep.error
        assert np.array_equal(buf, np.arange(n_tokens)), "synth corrupted"
        assert eng.compile_source in ("memory", "disk"), eng.compile_source
        if best is None or wall < best:
            best, sweeps = wall, eng.n_sweeps
    rows.append({"variant": "compiled",
                 "tokens_per_sec": round(hops / best, 1),
                 "sweeps": sweeps, "wall_s": round(best, 6),
                 "cold_source": cold_source})

    speedup = round(rows[1]["tokens_per_sec"] / rows[0]["tokens_per_sec"], 2)
    return {
        "config": {"n_tokens": n_tokens, "stages": stages, "burst": burst,
                   "capacity": capacity, "repeats": repeats,
                   "hops": hops},
        "rows": rows,
        "compiled_speedup_vs_twin": speedup,
    }


def build_fetch_pipeline(n_tokens: int, depth: int, latency: int):
    """One fetch task streaming ``n_tokens`` words through an async_mmap
    read port (warmup primes ``depth`` requests, steady state retires one
    response and issues the next address per firing) into a result mmap."""
    import jax.numpy as jnp

    import repro
    from repro import StepTask, mmap
    from repro.core import async_mmap

    data = np.arange(n_tokens, dtype=np.int32) * 3
    port = async_mmap(data.copy(), latency=latency, depth=depth, name="mem")
    buf = np.zeros(n_tokens, np.int32)
    res = mmap(buf, "res")
    d = min(depth, n_tokens)

    def warm(k, port, res):
        port.read_addr.write(k)
        return k + 1

    def step(k, port, res):
        res.write_burst(k - d, port.read_data.read()[None])
        port.read_addr.write(k)
        return k + 1

    def flush(k, port, res):
        res.write_burst(k - d, port.read_data.read()[None])
        return k + 1

    Fetch = StepTask(step, steps=n_tokens - d, init=jnp.int32(0),
                     warmup=warm, n_warmup=d, flush=flush, n_flush=d,
                     name="Fetch")

    def Top(port, res):
        repro.task().invoke(Fetch, port, res)

    return Top, (port, res), (data, buf)


def measure_interconnect(n_tokens: int, stages: int, burst: int,
                         capacity: int, repeats: int) -> dict:
    """The relay pipeline lowered with ring_impl="xla" vs the Pallas
    kernels ("pallas" on TPU, "interpret" elsewhere), both measured hot."""
    import repro
    from repro.kernels.dispatch import is_tpu

    kernel_impl = "pallas" if is_tpu() else "interpret"
    hops = n_tokens * (stages + 1)
    rows = []
    tps = {}
    for impl in ("xla", kernel_impl):
        top, args, buf = build_pipeline(n_tokens, stages, burst, capacity)
        repro.ENGINES["compiled"](ring_impl=impl).run(top, *args)  # cold
        best = None
        sweeps = None
        for _ in range(repeats):
            top, args, buf = build_pipeline(n_tokens, stages, burst,
                                            capacity)
            eng = repro.ENGINES["compiled"](ring_impl=impl)
            t0 = time.perf_counter()
            rep = eng.run(top, *args)
            wall = time.perf_counter() - t0
            assert rep.ok, rep.error
            assert np.array_equal(buf, np.arange(n_tokens)), impl
            if best is None or wall < best:
                best, sweeps = wall, eng.n_sweeps
        tps[impl] = hops / best
        rows.append({"variant": f"ring_{impl}",
                     "tokens_per_sec": round(tps[impl], 1),
                     "sweeps": sweeps, "wall_s": round(best, 6)})
    sec = {
        "config": {"n_tokens": n_tokens, "stages": stages, "burst": burst,
                   "capacity": capacity, "repeats": repeats, "hops": hops},
        "rows": rows,
        "kernel_impl": kernel_impl,
        "on_tpu": is_tpu(),
        "kernel_vs_xla_x": round(tps[kernel_impl] / tps["xla"], 3),
    }
    if not is_tpu():
        sec["gate_waived"] = (
            "no TPU backend: the ring/guard kernels ran under the Pallas "
            "interpreter, which emulates rather than accelerates; the "
            "ratio is recorded and the >=1.0x gate applies on TPU only")
    return sec


def measure_async_depth(n_tokens: int, latency: int, repeats: int,
                        depths=(1, 4)) -> dict:
    """Fetch throughput at outstanding depth 1 vs 4 against a
    ``latency``-sweep memory port — the compiled latency queue's
    issue-ahead payoff."""
    import repro

    rows = []
    tps = {}
    for depth in depths:
        top, args, (data, buf) = build_fetch_pipeline(n_tokens, depth,
                                                      latency)
        repro.ENGINES["compiled"]().run(top, *args)              # cold
        assert np.array_equal(buf, data), "fetch corrupted"
        best = None
        sweeps = None
        max_out = None
        for _ in range(repeats):
            top, args, (data, buf) = build_fetch_pipeline(n_tokens, depth,
                                                          latency)
            eng = repro.ENGINES["compiled"]()
            t0 = time.perf_counter()
            rep = eng.run(top, *args)
            wall = time.perf_counter() - t0
            assert rep.ok, rep.error
            assert np.array_equal(buf, data), "fetch corrupted"
            if best is None or wall < best:
                best, sweeps = wall, eng.n_sweeps
                max_out = args[0].max_outstanding_reads
        tps[depth] = n_tokens / best
        rows.append({"variant": f"depth{depth}",
                     "tokens_per_sec": round(tps[depth], 1),
                     "sweeps": sweeps, "max_outstanding_reads": max_out,
                     "wall_s": round(best, 6)})
    return {
        "config": {"n_tokens": n_tokens, "latency": latency,
                   "repeats": repeats, "depths": list(depths)},
        "rows": rows,
        "depth4_vs_depth1_x": round(tps[depths[-1]] / tps[depths[0]], 3),
    }


def measure_partition(P: int, n: int, K: int, repeats: int,
                      device_counts=(1, 2, 4)) -> dict:
    """The wide systolic gemm compiled single-device and floorplanned
    over each visible device count; every partitioned run must be a
    bit-twin of the 1-device program.  Tokens are the P*P*K block-MACs
    the PE array retires."""
    import jax

    import repro
    from repro.apps import gemm

    visible = jax.device_count()
    counts = [c for c in device_counts if c <= visible]
    tokens = P * P * K
    rows = []
    tps = {}
    predicted = {}
    golden = None
    for nd in counts:
        kw = {} if nd == 1 else {"mesh": nd}
        top, args, check = gemm.build_step(P=P, n=n, K=K)
        eng = repro.ENGINES["compiled"](**kw)
        rep = eng.run(top, *args)                                  # cold
        assert rep.ok, rep.error
        assert check()[0]
        got = np.concatenate([np.asarray(m.data) for m in args[2]])
        if golden is None:
            golden = got.copy()
        else:
            assert got.tobytes() == golden.tobytes(), \
                f"{nd}-device result is not a bit-twin of 1-device"
        placement = eng.placement_used
        best = None
        sweeps = None
        for _ in range(repeats):
            top, args, check = gemm.build_step(P=P, n=n, K=K)
            eng = repro.ENGINES["compiled"](**kw)
            t0 = time.perf_counter()
            rep = eng.run(top, *args)
            wall = time.perf_counter() - t0
            assert rep.ok, rep.error
            if best is None or wall < best:
                best, sweeps = wall, eng.n_sweeps
        tps[nd] = tokens / best
        row = {"variant": f"dev{nd}",
               "tokens_per_sec": round(tps[nd], 1),
               "sweeps": sweeps, "wall_s": round(best, 6),
               "vs_dev1_x": round(tps[nd] / tps[counts[0]], 3)}
        if placement is not None:
            ob = placement.objective
            predicted[nd] = sum(ob["loads_s"]) / ob["objective_s"]
            row.update({
                "partition_source": eng.partition_source,
                "cut_channels": len(ob["cut_channels"]),
                "cut_bytes": int(ob["cut_bytes"]),
                "max_load_s": ob["max_load_s"],
                "predicted_speedup_x": round(predicted[nd], 3)})
        rows.append(row)
    sec = {
        "config": {"P": P, "n": n, "K": K, "repeats": repeats,
                   "device_counts": counts, "tokens": tokens},
        "rows": rows,
        "devices_visible": visible,
        "host_cores": os.cpu_count(),
        "bit_identical": True,
        "measured_4dev_vs_1dev_x": (round(tps[4] / tps[1], 3)
                                    if 4 in tps else None),
        "predicted_4dev_vs_1dev_x": (round(predicted[4], 3)
                                     if 4 in predicted else None),
    }
    # the wall gate only means something when each device is real
    # compute: forced host-platform devices multiplex the same cores
    # (often ONE in CI), so device-level parallelism cannot improve
    # wall clock there
    real_parallelism = (jax.devices()[0].platform != "cpu"
                        or (os.cpu_count() or 1) >= 4)
    if 4 not in tps:
        sec["gate_waived"] = (f"only {visible} device(s) visible; the "
                              f"4-device gates need 4 (set XLA_FLAGS="
                              f"--xla_force_host_platform_device_count=8)")
    elif not real_parallelism:
        sec["gate_waived"] = (
            f"forced host-platform devices share {os.cpu_count()} "
            f"physical core(s): emulated device parallelism cannot "
            f"improve wall clock, so the measured "
            f"{sec['measured_4dev_vs_1dev_x']}x is recorded without "
            f"gating; the predicted-speedup gate still applies")
    return sec


def main(argv=None) -> dict:
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: smaller token volume, single repeat")
    args = ap.parse_args(argv)

    from repro.kernels.dispatch import resolve_impl
    from repro.kernels.ring import RING_CHOICES, RING_ENV
    ambient_impl = resolve_impl("ring", RING_ENV, RING_CHOICES,
                                fallback="xla")

    if args.quick:
        out = measure(n_tokens=4096, stages=8, burst=64, capacity=64,
                      repeats=1)
        out["pallas_interconnect"] = measure_interconnect(
            n_tokens=1024, stages=8, burst=32, capacity=32, repeats=1)
        out["async_depth"] = measure_async_depth(n_tokens=128, latency=8,
                                                 repeats=1)
        out["partition"] = measure_partition(P=4, n=64, K=16, repeats=1,
                                             device_counts=(1, 4))
    else:
        out = measure(n_tokens=16384, stages=8, burst=64, capacity=64,
                      repeats=2)
        out["pallas_interconnect"] = measure_interconnect(
            n_tokens=2048, stages=8, burst=32, capacity=32, repeats=2)
        out["async_depth"] = measure_async_depth(n_tokens=512, latency=8,
                                                 repeats=2)
        out["partition"] = measure_partition(P=4, n=64, K=16, repeats=2,
                                             device_counts=(1, 2, 4))

    cfg = out["config"]
    print(f"pipeline: {cfg['stages']} stages x {cfg['n_tokens']} tokens, "
          f"burst={cfg['burst']}, capacity={cfg['capacity']}")
    print(f"{'variant':<16} {'tokens/s':>14} {'wall_ms':>9}")
    for r in out["rows"]:
        print(f"{r['variant']:<16} {r['tokens_per_sec']:>14.0f} "
              f"{r['wall_s']*1e3:>9.1f}")
    print(f"compiled vs coroutine twin: "
          f"{out['compiled_speedup_vs_twin']}x (gate: >= {GATE_X}x)")

    ic = out["pallas_interconnect"]
    print(f"\ninterconnect kernels ({ic['kernel_impl']}, "
          f"{'TPU' if ic['on_tpu'] else 'no TPU'}):")
    for r in ic["rows"]:
        print(f"{r['variant']:<16} {r['tokens_per_sec']:>14.0f} "
              f"{r['wall_s']*1e3:>9.1f}")
    print(f"kernels vs xla reference: {ic['kernel_vs_xla_x']}x"
          + (f"  [gate waived: {ic['gate_waived']}]"
             if "gate_waived" in ic else "  (gate: >= 1.0x)"))

    ad = out["async_depth"]
    print(f"\nasync_mmap latency queue (latency="
          f"{ad['config']['latency']} sweeps):")
    for r in ad["rows"]:
        print(f"{r['variant']:<16} {r['tokens_per_sec']:>14.0f} "
              f"{r['wall_s']*1e3:>9.1f}  sweeps={r['sweeps']} "
              f"max_out={r['max_outstanding_reads']}")
    print(f"depth-4 vs depth-1: {ad['depth4_vs_depth1_x']}x "
          f"(gate: >= 1.0x)")

    pt = out["partition"]
    pcfg = pt["config"]
    print(f"\npartitioned gemm (P={pcfg['P']} n={pcfg['n']} K={pcfg['K']}, "
          f"{pt['devices_visible']} devices visible, "
          f"{pt['host_cores']} host core(s)):")
    for r in pt["rows"]:
        extra = (f"  cut={r['cut_channels']}ch/{r['cut_bytes']}B "
                 f"pred={r['predicted_speedup_x']}x "
                 f"[{r['partition_source']}]"
                 if "cut_channels" in r else "")
        print(f"{r['variant']:<16} {r['tokens_per_sec']:>14.0f} "
              f"{r['wall_s']*1e3:>9.1f}  x{r['vs_dev1_x']}{extra}")
    print(f"4-dev vs 1-dev: measured {pt['measured_4dev_vs_1dev_x']}x, "
          f"predicted {pt['predicted_4dev_vs_1dev_x']}x "
          f"(gate: >= {PARTITION_GATE_X}x)"
          + (f"  [wall gate waived: {pt['gate_waived']}]"
             if "gate_waived" in pt else ""))

    out["gate"] = {
        "required_x": GATE_X,
        "synth_regression": out["compiled_speedup_vs_twin"] < GATE_X,
        "pallas_regression": bool(ic["on_tpu"]
                                  and ic["kernel_vs_xla_x"] < 1.0),
        "async_depth_regression": ad["depth4_vs_depth1_x"] < 1.0,
        # measured-wall gate: only where device parallelism is real
        "partition_regression": bool(
            "gate_waived" not in pt
            and pt["measured_4dev_vs_1dev_x"] < PARTITION_GATE_X),
        # model gate: the floorplanner must FIND a placement whose own
        # objective predicts >= 1.5x at 4 devices — deterministic, so
        # enforced anywhere 4 devices are visible
        "partition_model_regression": bool(
            pt["predicted_4dev_vs_1dev_x"] is not None
            and pt["predicted_4dev_vs_1dev_x"] < PARTITION_GATE_X),
    }
    if out["gate"]["synth_regression"] and ambient_impl == "interpret":
        # $REPRO_RING_IMPL=interpret routes every channel op through the
        # Pallas interpreter — a correctness configuration, not a fast
        # one, so the 10x-twin gate is recorded as waived, not failed
        out["gate"]["synth_regression"] = False
        out["gate"]["synth_gate_waived"] = (
            f"ambient ring impl is 'interpret' (${RING_ENV}): "
            f"interpreter-emulated interconnect; speedup "
            f"{out['compiled_speedup_vs_twin']}x recorded without gating")
    write_bench("synth_time", out)
    print(f"wrote {BENCH_JSON}")
    if out["gate"]["synth_regression"]:
        print(f"SYNTH THROUGHPUT REGRESSION: "
              f"{out['compiled_speedup_vs_twin']}x < required {GATE_X}x")
    if out["gate"]["pallas_regression"]:
        print(f"PALLAS INTERCONNECT REGRESSION: "
              f"{ic['kernel_vs_xla_x']}x < required 1.0x on TPU")
    if out["gate"]["async_depth_regression"]:
        print(f"ASYNC DEPTH REGRESSION: depth-4 "
              f"{ad['depth4_vs_depth1_x']}x < 1.0x depth-1")
    if out["gate"]["partition_regression"]:
        print(f"PARTITION REGRESSION: 4-device "
              f"{pt['measured_4dev_vs_1dev_x']}x < required "
              f"{PARTITION_GATE_X}x 1-device")
    if out["gate"]["partition_model_regression"]:
        print(f"PARTITION MODEL REGRESSION: floorplanner predicts "
              f"{pt['predicted_4dev_vs_1dev_x']}x < required "
              f"{PARTITION_GATE_X}x at 4 devices")
    return out


if __name__ == "__main__":
    res = main()
    raise SystemExit(1 if (res["gate"]["synth_regression"]
                           or res["gate"]["pallas_regression"]
                           or res["gate"]["async_depth_regression"]
                           or res["gate"]["partition_regression"]
                           or res["gate"]["partition_model_regression"])
                     else 0)
