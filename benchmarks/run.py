"""Benchmark harness entry point — one section per paper table/figure plus
the roofline/dry-run reports.

    PYTHONPATH=src python -m benchmarks.run            # fast suite
    PYTHONPATH=src python -m benchmarks.run --full     # + recompute
                                                       #   roofline sweep

Sections:
  Fig. 5/6  lines-of-code with vs without the TAPA APIs   (loc.py)
  Fig. 7    simulation time, 3 engines x 7 benchmarks     (sim_time.py)
  Fig. 8    hierarchical vs monolithic codegen + the
            cold/warm/incremental compile-cache gates     (codegen_time.py)
  S:Synth   whole-graph synthesis vs its simulation twin  (synth_time.py)
  S:Serve   decode tokens/sec, per-slot vs batched        (serve_time.py)
  S:Dry-run 80-cell lower+compile summary                 (out/dryrun.json)
  S:Roofline three-term table                             (roofline.py)
  S:Perf    hillclimb log                                 (BENCH_perf_iter.json)
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

try:
    from benchmarks._bench import read_bench
except ImportError:                     # script mode: python benchmarks/run.py
    from _bench import read_bench

# scratch space for non-BENCH intermediates (dryrun cells, roofline md);
# the BENCH_*.json records live at the repo root — benchmarks/_bench.py is
# their single writer and out/ never holds a second copy
OUT = Path(__file__).parent / "out"


def section(title: str) -> None:
    print(f"\n{'=' * 72}\n{title}\n{'=' * 72}")


def dryrun_summary() -> None:
    p = OUT / "dryrun.json"
    if not p.exists():
        print("missing out/dryrun.json — run "
              "`python -m repro.launch.dryrun --arch all --mesh both`")
        return
    d = json.loads(p.read_text())
    ok = sum(1 for v in d.values() if v.get("ok") and "skipped" not in v)
    skip = sum(1 for v in d.values() if "skipped" in v)
    fail = [k for k, v in d.items() if not v.get("ok")]
    print(f"cells: {len(d)}  compiled-ok: {ok}  skipped(by-design): {skip}  "
          f"failed: {len(fail)} {fail or ''}")
    for mesh in ("pod16x16", "pod2x16x16"):
        cells = {k: v for k, v in d.items() if v.get("mesh") == mesh
                 and v.get("ok") and "skipped" not in v}
        if cells:
            worst = max(cells.values(),
                        key=lambda v: v.get("compile_s", 0))
            print(f"  {mesh}: {len(cells)} compiled, slowest compile "
                  f"{worst['compile_s']}s ({worst['arch']}|{worst['shape']})")


def roofline_summary() -> None:
    p = OUT / "roofline.md"
    if p.exists():
        print(p.read_text())
    else:
        print("missing out/roofline.md — run `python -m benchmarks.roofline`")


def perf_summary() -> None:
    rec = read_bench("perf_iter") or {}
    d = rec.get("cells")
    if not d and rec.get("rows"):
        # trajectory-only record (pre-`cells` schema): flat row display
        for r in rec["rows"]:
            if "error" in r:
                print(f"[{r['cell']}] {r['variant']:<28} "
                      f"ERROR {r['error'][:80]}")
                continue
            print(f"[{r['cell']}] {r['variant']:<28} "
                  f"comp={r['compute_s']*1e3:8.1f}ms "
                  f"mem={r['memory_s']*1e3:8.1f}ms "
                  f"coll={r['collective_s']*1e3:8.1f}ms "
                  f"dom={r['dominant']}")
        return
    if not d:
        print("missing BENCH_perf_iter.json — run "
              "`python -m benchmarks.perf_iter`")
        return
    for cell in d.values():
        print(f"\n[{cell['cell']}] {cell['arch']} | {cell['shape']}")
        for v in cell["variants"]:
            if "error" in v:
                print(f"  {v['variant']:<28} ERROR {v['error'][:80]}")
                continue
            dl = v.get("delta_vs_prev")
            dl = (f"  dx(prev/this): comp {dl['compute_s']}x "
                  f"mem {dl['memory_s']}x coll {dl['collective_s']}x"
                  if dl else "")
            print(f"  {v['variant']:<28} comp={v['compute_s']*1e3:8.1f}ms "
                  f"mem={v['memory_s']*1e3:8.1f}ms "
                  f"coll={v['collective_s']*1e3:8.1f}ms "
                  f"hbm={v['hbm_per_dev_gb']:5.1f}GB "
                  f"dom={v['dominant']}{dl}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also (re)compute the roofline sweep (slow)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: shrink the simulation/throughput sizes")
    args = ap.parse_args(argv)

    # before anything imports jax: the synth partition section needs a
    # multi-device host platform (real accelerators are unaffected)
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

    from benchmarks import (codegen_time, loc, serve_time, sim_time,
                            synth_time)

    section("Fig. 5/6 — lines of code (with vs without TAPA APIs)")
    loc.main()
    section("Fig. 7 + throughput — software simulation (3 engines) and "
            "burst tokens/sec (emits BENCH_sim_time.json)")
    sim_res = sim_time.main(["--quick"] if args.quick else [])
    section("Fig. 8 + cache — code generation: hierarchical vs monolithic, "
            "cold/warm/incremental (emits BENCH_codegen_time.json)")
    codegen_res = codegen_time.main(["--quick"] if args.quick else [])
    section("S:Synth — whole-graph synthesis vs its coroutine simulation "
            "twin (emits BENCH_synth_time.json)")
    synth_res = synth_time.main(["--quick"] if args.quick else [])
    section("S:Serve — decode tokens/sec, per-slot seed vs batched packed "
            "slots (emits BENCH_serve_time.json)")
    serve_res = serve_time.main(["--quick"] if args.quick else [])
    if args.full:
        from benchmarks import roofline
        section("S:Roofline (recomputing)")
        roofline.main([])
    section("S:Dry-run — 80-cell multi-pod compile summary")
    dryrun_summary()
    section("S:Roofline — per (arch x shape), 16x16 pod")
    roofline_summary()
    section("S:Perf — hillclimb log (3 cells)")
    perf_summary()
    # propagate every regression gate through the umbrella runner; the
    # BENCH_*.json files share one schema (benchmark/config/rows/gates)
    return 1 if (sim_res.get("throughput_regression")
                 or sim_res.get("fault_overhead_regression")
                 or codegen_res.get("codegen_regression")
                 or synth_res["gate"]["synth_regression"]
                 or synth_res["gate"].get("pallas_regression")
                 or synth_res["gate"].get("async_depth_regression")
                 or synth_res["gate"].get("partition_regression")
                 or synth_res["gate"].get("partition_model_regression")
                 or serve_res["gate"]["serve_regression"]
                 or serve_res["gate"].get("overload_regression")) else 0


if __name__ == "__main__":
    sys.exit(main())
