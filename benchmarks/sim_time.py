"""Fig. 7 reproduction: software-simulation time per engine per benchmark,
plus a tokens/sec channel-throughput benchmark for the burst API.

Paper claims validated here:
  * the sequential simulator FAILS on cannon and page_rank (feedback);
  * the coroutine simulator correctly simulates ALL benchmarks;
  * coroutine beats the preemptive-thread simulator (3.2x average in the
    paper on 2x Xeon Gold; our ratio is measured on this host and grows
    with task count because thread scheduling costs OS context switches
    where the coroutine engine pays a user-level handoff).

Throughput section (this repo's extension): a deep Source -> N x Relay ->
Sink pipeline moves a fixed token volume under three channel-I/O variants:

  seed_scalar   per-token runtime dispatch with per-token stats — the seed
                implementation's hot path (``track_stats=True``);
  scalar_fast   per-token ops on the lock-free run-to-block fast path;
  burst         ``write_burst``/``read_burst`` batched transfers.

Results (engine, variant, tokens/sec, switches, wall) are persisted to
``BENCH_sim_time.json`` at the repo root so the perf trajectory
accumulates across PRs.  The acceptance bar: coroutine burst must be
>= 3x coroutine seed_scalar tokens/sec on the >= 8-stage pipeline.

Sizes are scaled so the full suite simulates in seconds; ``--paper-scale``
raises instance counts to the paper's Table 3 neighbourhood; ``--quick``
shrinks everything for CI smoke runs.
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import repro
from repro.apps import APPS, FEEDBACK_APPS

try:
    from benchmarks._bench import bench_path, write_bench
except ImportError:                     # script mode: python benchmarks/...
    from _bench import bench_path, write_bench

BENCH_JSON = bench_path("sim_time")

# per-app size overrides: (fast, paper-ish)
SIZES = {
    "cannon": ({"P": 4, "n": 8}, {"P": 8, "n": 8}),
    "cnn": ({"ci": 8, "co": 8, "hw": 6, "P": 2}, {"ci": 16, "co": 16,
                                                  "hw": 8, "P": 4}),
    "gaussian": ({"h": 12, "w": 12, "iters": 4}, {"h": 16, "w": 16,
                                                  "iters": 8}),
    "gcn": ({"n_vertices": 64, "n_edges": 256}, {"n_vertices": 256,
                                                 "n_edges": 1024}),
    "gemm": ({"P": 4, "n": 8, "K": 4}, {"P": 8, "n": 8, "K": 8}),
    "network": ({"n_packets": 64}, {"n_packets": 512}),
    "page_rank": ({"n_vertices": 32, "n_edges": 128, "n_pe": 2},
                  {"n_vertices": 64, "n_edges": 512, "n_pe": 4}),
}

ENGINES = ("sequential", "thread", "coroutine")


def run(paper_scale: bool = False, repeats: int = 3) -> dict:
    rows = []
    for name, mod in APPS.items():
        kw = SIZES[name][1 if paper_scale else 0]
        row: dict = {"app": name, "sizes": kw}
        for eng in ENGINES:
            best = None
            ok = correct = None
            for _ in range(repeats):
                r = mod.run(engine=eng, **kw)
                ok, correct = r.report.ok, r.correct
                if ok:
                    best = min(best or 1e9, r.report.wall_s)
                row["instances"] = r.report.n_instances
                row["channels"] = r.report.n_channels
            row[eng] = {"ok": ok, "correct": correct,
                        "wall_s": best}
        if row["thread"]["ok"] and row["coroutine"]["ok"]:
            row["coroutine_speedup_vs_thread"] = round(
                row["thread"]["wall_s"] / row["coroutine"]["wall_s"], 2)
        rows.append(row)

    # paper-claim assertions
    for row in rows:
        app = row["app"]
        assert row["coroutine"]["ok"] and row["coroutine"]["correct"], app
        assert row["thread"]["ok"] and row["thread"]["correct"], app
        if app in FEEDBACK_APPS:
            assert not row["sequential"]["ok"], \
                f"{app} must fail sequential simulation (paper Fig. 7)"

    ratios = [r["coroutine_speedup_vs_thread"] for r in rows
              if "coroutine_speedup_vs_thread" in r]
    geo = 1.0
    for x in ratios:
        geo *= x
    geo = geo ** (1.0 / len(ratios))
    return {"rows": rows, "coroutine_vs_thread_geomean": round(geo, 2),
            "paper_claim": "3.2x average (engine-level; paper's cycle "
                           "includes compile+run)"}


# ---------------------------------------------------------------------------
# tokens/sec throughput: deep pipeline, scalar vs burst channel I/O
# ---------------------------------------------------------------------------

def _build_pipeline(n_tokens: int, stages: int, capacity: int, burst: int):
    """Source -> ``stages`` x Relay -> Sink moving ``n_tokens`` integers.

    ``burst`` == 0 selects the scalar (per-token) API; > 0 moves tokens in
    bursts of that size.  Returns (Top, sink_total) where sink_total[0]
    counts tokens that reached the sink (correctness check).
    """
    sink_total = [0]
    if burst:
        def Source(o):
            o.write_burst(list(range(n_tokens)))
            o.close()

        def Relay(i, o):
            while True:
                chunk = i.read_burst(burst)
                if chunk:
                    o.write_burst(chunk)
                if len(chunk) < burst:
                    break
            i.open()
            o.close()

        def Sink(i):
            while True:
                chunk = i.read_burst(burst)
                sink_total[0] += len(chunk)
                if len(chunk) < burst:
                    break
            i.open()
    else:
        def Source(o):
            for v in range(n_tokens):
                o.write(v)
            o.close()

        def Relay(i, o):
            for v in i:
                o.write(v)
            o.close()

        def Sink(i):
            for _ in i:
                sink_total[0] += 1

    def Top():
        chans = [repro.channel(capacity=capacity) for _ in range(stages + 1)]
        t = repro.task().invoke(Source, chans[0])
        for s in range(stages):
            t = t.invoke(Relay, chans[s], chans[s + 1], name=f"Relay{s}")
        t.invoke(Sink, chans[stages])

    return Top, sink_total


# (variant label, burst?, track_stats?) — seed_scalar reproduces the seed
# implementation's per-token dispatch + per-token stats hot path.
VARIANTS = (
    ("seed_scalar", 0, True),
    ("scalar_fast", 0, False),
    ("burst", 1, False),
)


def throughput(n_tokens: int = 20000, stages: int = 8, capacity: int = 64,
               burst: int = 64, repeats: int = 3,
               engines: tuple = ("sequential", "thread", "coroutine")) -> dict:
    """Measure tokens/sec per (engine, variant) on the deep pipeline.

    tokens/sec counts every channel hop: ``n_tokens * (stages + 1)``
    transfers divided by the best wall time over ``repeats`` runs.
    """
    hops = n_tokens * (stages + 1)
    rows = []
    for eng in engines:
        for label, use_burst, stats in VARIANTS:
            best = None
            switches = None
            for _ in range(repeats):
                top, total = _build_pipeline(
                    n_tokens, stages, capacity, burst if use_burst else 0)
                rep = repro.ENGINES[eng](track_stats=stats).run(top)
                assert rep.ok, (eng, label, rep.error)
                assert total[0] == n_tokens, (eng, label, total[0])
                if best is None or rep.wall_s < best:
                    best = rep.wall_s
                    switches = rep.switches
            rows.append({
                "engine": eng, "variant": label,
                "tokens_per_sec": round(hops / best, 1),
                "switches": switches, "wall_s": round(best, 6),
                "tokens_moved": hops,
            })

    def tps(engine, variant):
        for r in rows:
            if r["engine"] == engine and r["variant"] == variant:
                return r["tokens_per_sec"]
        return None

    out = {
        "config": {"n_tokens": n_tokens, "stages": stages,
                   "capacity": capacity, "burst": burst,
                   "repeats": repeats},
        "rows": rows,
    }
    coro_seed = tps("coroutine", "seed_scalar")
    coro_burst = tps("coroutine", "burst")
    thr_scalar = tps("thread", "seed_scalar")
    if coro_seed and coro_burst:
        out["coroutine_burst_speedup_vs_seed"] = round(
            coro_burst / coro_seed, 2)
    if thr_scalar and coro_burst:
        out["coroutine_burst_speedup_vs_thread_seed"] = round(
            coro_burst / thr_scalar, 2)
    return out


def fault_overhead(n_tokens: int = 20000, stages: int = 8,
                   capacity: int = 64, repeats: int = 5) -> dict:
    """Cost of chaos-readiness when no fault plan targets channels.

    An armed-but-empty :class:`repro.FaultPlan` must leave the coroutine
    scalar fast path intact (``affects_channels`` is False, so the engine
    keeps ``_chan_faults = None`` and ``fast_path`` on) — the acceptance
    bar is < 5% overhead versus a run with no injector at all.  The same
    bar applies to supervised execution with snapshots disabled
    (``repro.ft.run_supervised`` with ``store=None``), which must delegate
    straight to the plain engine.  All variants are interleaved within
    each repeat so host drift cancels.
    """
    from repro import FaultPlan
    from repro.ft import run_supervised

    def _plain(plan, top):
        return repro.ENGINES["coroutine"](faults=plan).run(top)

    def _supervised(plan, top):
        return run_supervised("coroutine", top, store=None, faults=plan)

    variants = (("baseline", None, _plain),
                ("noop_plan", FaultPlan(), _plain),
                ("supervised", None, _supervised))
    best: dict = {label: None for label, _, _ in variants}
    for _ in range(repeats):
        for label, plan, runner in variants:
            top, total = _build_pipeline(n_tokens, stages, capacity, 0)
            rep = runner(plan, top)
            assert rep.ok, (label, rep.error)
            assert total[0] == n_tokens, (label, total[0])
            if best[label] is None or rep.wall_s < best[label]:
                best[label] = rep.wall_s
    pct = (best["noop_plan"] / best["baseline"] - 1.0) * 100
    sup_pct = (best["supervised"] / best["baseline"] - 1.0) * 100
    return {"baseline_wall_s": round(best["baseline"], 6),
            "noop_plan_wall_s": round(best["noop_plan"], 6),
            "overhead_pct": round(pct, 2),
            "supervised_wall_s": round(best["supervised"], 6),
            "supervised_overhead_pct": round(sup_pct, 2)}


def write_bench_json(thr: dict, apps: Optional[dict] = None) -> None:
    """Persist the perf trajectory record (consumed by benchmarks/run.py
    and CI regression checks) — the app-simulation section rides along in
    the same root file instead of a duplicate under benchmarks/out/."""
    payload = {"benchmark": "sim_time", **thr}
    if apps:
        payload["apps"] = apps
    write_bench("sim_time", payload)


def print_throughput(thr: dict) -> None:
    cfg = thr["config"]
    print(f"pipeline: {cfg['stages']} stages x {cfg['n_tokens']} tokens, "
          f"capacity={cfg['capacity']}, burst={cfg['burst']}")
    print(f"{'engine':<11} {'variant':<12} {'tokens/s':>12} "
          f"{'switches':>9} {'wall_ms':>9}")
    for r in thr["rows"]:
        print(f"{r['engine']:<11} {r['variant']:<12} "
              f"{r['tokens_per_sec']:>12.0f} {r['switches']:>9} "
              f"{r['wall_s']*1e3:>9.1f}")
    if "coroutine_burst_speedup_vs_seed" in thr:
        print(f"coroutine burst vs seed per-token path: "
              f"{thr['coroutine_burst_speedup_vs_seed']}x "
              f"(acceptance bar: >= 3x)")
    if "coroutine_burst_speedup_vs_thread_seed" in thr:
        print(f"coroutine burst vs thread seed path:    "
              f"{thr['coroutine_burst_speedup_vs_thread_seed']}x")


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: tiny sizes, single repeat")
    ap.add_argument("--paper-scale", action="store_true",
                    help="raise app sizes to the paper's Table 3 "
                         "neighbourhood")
    ap.add_argument("--skip-apps", action="store_true",
                    help="only run the throughput section")
    args = ap.parse_args(argv)

    out: dict = {}
    if not args.skip_apps:
        out = run(paper_scale=args.paper_scale,
                  repeats=1 if args.quick else 3)
        print(f"{'app':<10} {'insts':>5} {'chans':>5} "
              f"{'seq_ms':>8} {'thread_ms':>9} {'coro_ms':>8} {'coro/thr':>8}")
        for r in out["rows"]:
            seq = r["sequential"]
            fmt = lambda e: f"{e['wall_s']*1e3:8.1f}" if e["ok"] else "    FAIL"
            print(f"{r['app']:<10} {r['instances']:>5} {r['channels']:>5} "
                  f"{fmt(seq)} {fmt(r['thread']):>9} {fmt(r['coroutine']):>8} "
                  f"{r.get('coroutine_speedup_vs_thread', '-'):>8}")
        print(f"coroutine vs thread geomean speedup: "
              f"{out['coroutine_vs_thread_geomean']}x")

    print()
    if args.quick:
        thr = throughput(n_tokens=4000, stages=8, repeats=1)
        fo = fault_overhead(n_tokens=4000, stages=8, repeats=3)
    else:
        thr = throughput()
        fo = fault_overhead()
    thr["fault_overhead"] = fo
    print(f"no-op fault-plan overhead on coroutine scalar_fast: "
          f"{fo['overhead_pct']}% (acceptance bar: < 5%)")
    print(f"snapshot-disabled supervisor overhead: "
          f"{fo['supervised_overhead_pct']}% (same bar)")
    print_throughput(thr)
    write_bench_json(thr, apps=out or None)
    print(f"wrote {BENCH_JSON}")
    out["throughput"] = thr

    # regression gate: the burst path must stay comfortably ahead of the
    # seed per-token path (quick mode uses a lower bar for CI-host noise)
    bar = 2.0 if args.quick else 3.0
    speedup = thr.get("coroutine_burst_speedup_vs_seed", 0.0)
    if speedup < bar:
        print(f"THROUGHPUT REGRESSION: coroutine burst speedup {speedup}x "
              f"< required {bar}x")
        out["throughput_regression"] = True
    # chaos gate: an empty fault plan must be structurally free on the hot
    # path (quick mode doubles the bar — tiny runs amplify timer noise)
    fo_bar = 10.0 if args.quick else 5.0
    if fo["overhead_pct"] > fo_bar:
        print(f"FAULT-OVERHEAD REGRESSION: no-op plan costs "
              f"{fo['overhead_pct']}% > allowed {fo_bar}%")
        out["fault_overhead_regression"] = True
    # recovery gate: the supervisor with snapshots disabled must be a
    # plain-engine delegation, not a second scheduling layer
    if fo["supervised_overhead_pct"] > fo_bar:
        print(f"FAULT-OVERHEAD REGRESSION: snapshot-disabled supervisor "
              f"costs {fo['supervised_overhead_pct']}% > allowed {fo_bar}%")
        out["fault_overhead_regression"] = True
    return out


if __name__ == "__main__":
    res = main()
    raise SystemExit(1 if (res.get("throughput_regression")
                           or res.get("fault_overhead_regression")) else 0)
