"""Fig. 7 reproduction: software-simulation time per engine per benchmark.

Paper claims validated here:
  * the sequential simulator FAILS on cannon and page_rank (feedback);
  * the coroutine simulator correctly simulates ALL benchmarks;
  * coroutine beats the preemptive-thread simulator (3.2x average in the
    paper on 2x Xeon Gold; our ratio is measured on this host and grows
    with task count because thread scheduling costs OS context switches
    where the coroutine engine pays a user-level handoff).

Sizes are scaled so the full suite simulates in seconds; ``--paper-scale``
raises instance counts to the paper's Table 3 neighbourhood.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from repro.apps import APPS, FEEDBACK_APPS

OUT = Path(__file__).parent / "out"

# per-app size overrides: (fast, paper-ish)
SIZES = {
    "cannon": ({"P": 4, "n": 8}, {"P": 8, "n": 8}),
    "cnn": ({"ci": 8, "co": 8, "hw": 6, "P": 2}, {"ci": 16, "co": 16,
                                                  "hw": 8, "P": 4}),
    "gaussian": ({"h": 12, "w": 12, "iters": 4}, {"h": 16, "w": 16,
                                                  "iters": 8}),
    "gcn": ({"n_vertices": 64, "n_edges": 256}, {"n_vertices": 256,
                                                 "n_edges": 1024}),
    "gemm": ({"P": 4, "n": 8, "K": 4}, {"P": 8, "n": 8, "K": 8}),
    "network": ({"n_packets": 64}, {"n_packets": 512}),
    "page_rank": ({"n_vertices": 32, "n_edges": 128, "n_pe": 2},
                  {"n_vertices": 64, "n_edges": 512, "n_pe": 4}),
}

ENGINES = ("sequential", "thread", "coroutine")


def run(paper_scale: bool = False, repeats: int = 3) -> dict:
    rows = []
    for name, mod in APPS.items():
        kw = SIZES[name][1 if paper_scale else 0]
        row: dict = {"app": name, "sizes": kw}
        for eng in ENGINES:
            best = None
            ok = correct = None
            for _ in range(repeats):
                r = mod.run(engine=eng, **kw)
                ok, correct = r.report.ok, r.correct
                if ok:
                    best = min(best or 1e9, r.report.wall_s)
                row["instances"] = r.report.n_instances
                row["channels"] = r.report.n_channels
            row[eng] = {"ok": ok, "correct": correct,
                        "wall_s": best}
        if row["thread"]["ok"] and row["coroutine"]["ok"]:
            row["coroutine_speedup_vs_thread"] = round(
                row["thread"]["wall_s"] / row["coroutine"]["wall_s"], 2)
        rows.append(row)

    # paper-claim assertions
    for row in rows:
        app = row["app"]
        assert row["coroutine"]["ok"] and row["coroutine"]["correct"], app
        assert row["thread"]["ok"] and row["thread"]["correct"], app
        if app in FEEDBACK_APPS:
            assert not row["sequential"]["ok"], \
                f"{app} must fail sequential simulation (paper Fig. 7)"

    ratios = [r["coroutine_speedup_vs_thread"] for r in rows
              if "coroutine_speedup_vs_thread" in r]
    geo = 1.0
    for x in ratios:
        geo *= x
    geo = geo ** (1.0 / len(ratios))
    return {"rows": rows, "coroutine_vs_thread_geomean": round(geo, 2),
            "paper_claim": "3.2x average (engine-level; paper's cycle "
                           "includes compile+run)"}


def main() -> dict:
    out = run()
    OUT.mkdir(exist_ok=True)
    (OUT / "sim_time.json").write_text(json.dumps(out, indent=1))
    print(f"{'app':<10} {'insts':>5} {'chans':>5} "
          f"{'seq_ms':>8} {'thread_ms':>9} {'coro_ms':>8} {'coro/thr':>8}")
    for r in out["rows"]:
        seq = r["sequential"]
        fmt = lambda e: f"{e['wall_s']*1e3:8.1f}" if e["ok"] else "    FAIL"
        print(f"{r['app']:<10} {r['instances']:>5} {r['channels']:>5} "
              f"{fmt(seq)} {fmt(r['thread']):>9} {fmt(r['coroutine']):>8} "
              f"{r.get('coroutine_speedup_vs_thread', '-'):>8}")
    print(f"coroutine vs thread geomean speedup: "
          f"{out['coroutine_vs_thread_geomean']}x")
    return out


if __name__ == "__main__":
    main()
