"""Fig. 5/6 reproduction: lines-of-code with and without the TAPA APIs.

The paper counts kernel LoC (−22% avg) and host LoC (−51% avg).  The same
patterns exist in this framework, so we measure them the same way — the
*without* variants are written exactly as the paper's red listings force
one to (manual peek buffer + state machine; manual EoT struct wrapping;
verbose runtime setup), the *with* variants use the Table-2 API.  All
variants are real code from this repository or its tests, embedded here
verbatim so the counter is auditable.  Counting rule: non-blank,
non-comment lines (the paper's convention).
"""

from __future__ import annotations


# --- Listing 1: peek vs manual buffer (update-count accumulate) --------------

PEEK_WITH = """
def UpdateCounter(inp, counts, commit):
    last_pid, count = -1, 0
    while not inp.eot():
        pid = inp.peek()[0]                  # peek: no consume
        if pid != last_pid and last_pid >= 0:
            counts[last_pid] = count         # commit on pid change
            count = counts[pid]
        upd = inp.read()
        count += 1
        last_pid = pid
    inp.open()
    if last_pid >= 0:
        counts[last_pid] = count
"""

PEEK_WITHOUT = """
def UpdateCounter(inp, counts, commit):
    buf, buf_valid = None, False             # manual head buffer
    last_pid, count = -1, 0
    done = False
    while not done:
        if buf_valid:
            pid = buf[0]
        else:
            ok, tok = inp.try_read()
            if not ok:
                ok_eot, is_eot = inp.try_eot()
                if ok_eot and is_eot:
                    inp.open()
                    done = True
                    continue
                continue
            buf, buf_valid = tok, True
            pid = buf[0]
        if pid != last_pid and last_pid >= 0:
            counts[last_pid] = count
            count = counts[pid]
        upd = buf                            # consume the buffered token
        buf_valid = False
        count += 1
        last_pid = pid
    if last_pid >= 0:
        counts[last_pid] = count
"""

# --- Listing 2: EoT vs manual sentinel field ---------------------------------

EOT_WITH = """
def ComputeUnit(inp, out):
    while True:
        acc = 0.0
        for upd in inp:                      # drains one transaction
            acc += upd.value
        out.write(acc)
"""

EOT_WITHOUT = """
class UpdateWithEot:                         # widened token type
    def __init__(self, update, eot):
        self.update = update
        self.eot = eot

def ComputeUnit(inp, out):
    while True:
        acc = 0.0
        while True:
            tok = inp.read()
            if tok.eot:                      # in-band sentinel test
                break
            acc += tok.update.value
        out.write(acc)
"""

# --- Listing 3 + host: one-call invoke vs manual runtime setup ---------------

HOST_WITH = """
import repro

def main(graph, ranks):
    result = repro.invoke(PageRank, graph, ranks, target="sim")
    return result
"""

HOST_WITHOUT = """
from repro.core.engines import CoroutineEngine
from repro.core.graph import extract_graph
from repro.core.hier_compile import StageInstance, compile_stages

def main(graph, ranks):
    engine = CoroutineEngine()               # pick + build an engine
    report = engine.run(PageRank, graph, ranks)
    if not report.ok:                        # error plumbing by hand
        raise RuntimeError(report.error)
    g = extract_graph(engine, report)        # metadata extraction
    g.validate()
    stages = []
    for inst in g.instances:                 # manual stage collection
        if inst.children:
            continue
        stages.append(StageInstance(fn=inst.fn, args=inst.args,
                                    kwargs=inst.kwargs, name=inst.name))
    compile_stages(stages, mode="hierarchical")
    for inst in stages:                      # manual executable wiring
        if inst.executable is None:
            raise RuntimeError(f"stage {inst.name} failed to compile")
    return report.result
"""

PAIRS = {
    "kernel:peek (Listing 1)": (PEEK_WITH, PEEK_WITHOUT),
    "kernel:eot (Listing 2)": (EOT_WITH, EOT_WITHOUT),
    "host:invoke (S3.1.4)": (HOST_WITH, HOST_WITHOUT),
}


def count_loc(src: str) -> int:
    return sum(1 for ln in src.splitlines()
               if ln.strip() and not ln.strip().startswith("#"))


def main() -> dict:
    rows = []
    for name, (with_api, without) in PAIRS.items():
        a, b = count_loc(with_api), count_loc(without)
        rows.append({"pattern": name, "with_api": a, "without_api": b,
                     "reduction_pct": round(100 * (1 - a / b), 1)})
    kernel = [r for r in rows if r["pattern"].startswith("kernel")]
    host = [r for r in rows if r["pattern"].startswith("host")]
    out = {
        "rows": rows,
        "kernel_reduction_avg_pct": round(
            sum(r["reduction_pct"] for r in kernel) / len(kernel), 1),
        "host_reduction_pct": host[0]["reduction_pct"],
        "paper_claims": {"kernel": "22% avg", "host": "51% avg"},
    }
    for r in rows:
        print(f"{r['pattern']:<26} with={r['with_api']:>3} "
              f"without={r['without_api']:>3}  -{r['reduction_pct']}%")
    print(f"kernel avg -{out['kernel_reduction_avg_pct']}% "
          f"(paper: -22%);  host -{out['host_reduction_pct']}% "
          f"(paper: -51%)")
    return out


if __name__ == "__main__":
    main()
