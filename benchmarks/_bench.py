"""Single writer for the root ``BENCH_*.json`` artifacts.

Every benchmark section builds a payload dict and hands it to
:func:`write_bench` — the one code path that serializes to the repo root.
``benchmarks/out/`` is scratch space only (gitignored): incremental sweep
state and large intermediate reports live there, but never a second copy
of a BENCH file.  ``benchmarks/run.py`` (and CI) read the same root files
back through :func:`read_bench`.
"""

from __future__ import annotations

import json
import os
import platform
from pathlib import Path
from typing import Any, Optional

ROOT = Path(__file__).parent.parent


def machine_info() -> dict:
    """Host fingerprint recorded in every BENCH_*.json.

    Absolute wall times in these records are only comparable within one
    machine; regression gates therefore compare *relative ratios* (e.g.
    burst-vs-scalar speedup, overhead percentages) measured in the same
    run, never absolute times across records.  The fingerprint makes it
    obvious when two records came from different hosts.
    """
    info = {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "cpu_count": os.cpu_count(),
    }
    try:
        import jax
        info["jax"] = jax.__version__
        info["jax_backend"] = jax.default_backend()
        # partition benches depend on how many devices were visible
        # (host devices under --xla_force_host_platform_device_count
        # count too) — record it so a 1-device record is never compared
        # against an 8-device one.
        info["device_count"] = jax.device_count()
        info["device_platform"] = jax.devices()[0].platform
        info["xla_flags"] = os.environ.get("XLA_FLAGS", "")
    except Exception:  # noqa: BLE001 - benches that never import jax
        pass
    return info


def bench_path(name: str) -> Path:
    return ROOT / f"BENCH_{name}.json"


def write_bench(name: str, payload: dict) -> Path:
    """Persist one benchmark's record to the repo root (shared schema:
    ``benchmark`` / ``config`` / ``rows`` / ``machine`` / gates)."""
    payload.setdefault("benchmark", name)
    payload.setdefault("machine", machine_info())
    p = bench_path(name)
    p.write_text(json.dumps(payload, indent=1) + "\n")
    return p


def read_bench(name: str) -> Optional[Any]:
    p = bench_path(name)
    return json.loads(p.read_text()) if p.exists() else None
