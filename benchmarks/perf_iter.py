"""S:Perf — hypothesis-driven hillclimbing on the three chosen cells.

Cell selection (from the S:Roofline baseline table):
  * qwen3-0.6b | train_4k   — worst roofline fraction among trains; memory-
    bound on materialized [S, S] attention scores.
  * granite-moe-1b-a400m | train_4k — most collective-bound train (GSPMD
    lowers the MoE scatter/gather dispatch into pod-wide all-reduces).
  * grok-1-314b | decode_32k — most collective-bound overall (FSDP weight
    all-gathers per decoded token) AND an HBM-capacity violation the
    per-device memory analysis exposes (68 GB/chip of batch-sharded KV).

Each variant records: hypothesis -> napkin-math prediction -> measured
before/after -> confirmed/refuted.  Variants are CUMULATIVE within a cell
(each builds on the previous winner) unless marked independent.

Measurements run through the **incremental path** of the compile cache
(core/compile_cache.py): every (config, shape, sharding, variant) build is
memoized in the content-addressed store under the structural hash of the
step function, so re-running the hillclimb after editing ONE variant
re-measures only that variant — the paper's QoR-tuning cycle shape.  The
trajectory (per-variant terms + whether the measurement was a memo hit)
is persisted to ``BENCH_perf_iter.json`` at the repo root alongside the
other BENCH files.

Run:  PYTHONPATH=src python -m benchmarks.perf_iter [--cell name]
      [--no-memo]   # force fresh measurements
"""

from __future__ import annotations

import argparse
import dataclasses

import os
import time
from pathlib import Path

try:
    from benchmarks._bench import read_bench, write_bench
except ImportError:                     # script mode: python benchmarks/...
    from _bench import read_bench, write_bench

from repro.core.cost import HW          # shared with the floorplanner

_CODE_SALT = None


def _code_salt() -> str:
    """Digest of the model/step source tree, folded into memo keys.

    The structural hash covers the step function's own code and closures,
    but model code reached through module attributes (``lm.loss_fn`` etc.)
    is hashed by module *name* only — so an edit to src/repro/models or
    launch/steps.py must dirty the memo some other way: this salt.
    """
    global _CODE_SALT
    if _CODE_SALT is None:
        import hashlib
        h = hashlib.sha256()
        src = Path(__file__).resolve().parents[1] / "src" / "repro"
        files = sorted((src / "models").glob("*.py")) + \
            sorted((src / "distributed").glob("*.py")) + \
            [src / "launch" / "steps.py", src / "launch" / "dryrun.py"]
        for f in files:
            h.update(f.name.encode())
            h.update(f.read_bytes())
        _CODE_SALT = h.hexdigest()
    return _CODE_SALT


def _measure_variant(cfg, shape, mesh, *, pol=None, scan_layers=True,
                     remat=True, opt=None, memo=True):
    """Full fit-corrected terms + per-device memory for one build.

    Each probe build is memoized in the compile cache's JSON store under
    the structural hash of its step function (which bakes in cfg via its
    closure) + sharding/mesh geometry: the incremental path.  An edited
    variant hashes different and re-measures; everything untouched is a
    digest lookup.  The probe itself is ``repro.core.cost.probe_compiled``
    — the same machinery that prices step tasks for the floorplanner.
    """
    from benchmarks import roofline as RL
    from repro.core.compile_cache import instance_key
    from repro.core.cost import probe_compiled
    from repro.launch.steps import input_specs

    # fit-corrected flops/bytes/coll (handles the scan single-count)
    def meas(c, scan):
        spec = input_specs(c, shape, mesh, pol=pol, scan_layers=scan,
                           remat=remat, opt=opt)
        key = None
        if memo:
            key = instance_key(
                spec["fn"], spec["args"], {},
                extra=("perf_iter", _code_salt(), repr(pol), bool(scan),
                       bool(remat), repr(opt), repr(shape),
                       tuple(sorted((k, int(v))
                             for k, v in mesh.shape.items()))))
        return probe_compiled(
            spec["fn"], spec["args"], mesh=mesh,
            in_shardings=spec["in_shardings"],
            out_shardings=spec["out_shardings"],
            donate_argnums=spec["donate_argnums"],
            memo_key=key, cache=None if memo else False)

    keys = ("flops", "bytes", "coll")
    L = cfg.n_layers
    p_small = 2 if cfg.hybrid is not None else None
    s2 = meas(RL._variant_cfg(cfg, 2, period=p_small), True)
    s4 = meas(RL._variant_cfg(cfg, 4, period=p_small), True)
    m_scan = meas(cfg, True)
    full = {}
    for k in keys:
        if s4[k] > 1.6 * max(s2[k], 1.0):
            full[k] = m_scan[k]                       # trip-accounted
        else:
            u2 = meas(RL._variant_cfg(cfg, 2, period=p_small), False)
            u4 = meas(RL._variant_cfg(cfg, 4, period=p_small), False)
            per = (u4[k] - u2[k]) / 2.0
            full[k] = max(u2[k] - 2 * per + L * per, 0.0)
    return {
        "compute_s": full["flops"] / HW["peak_flops"],
        "memory_s": full["bytes"] / HW["hbm_bw"],
        "collective_s": full["coll"] / HW["ici_bw"],
        "hbm_per_dev_gb": (m_scan["arg_bytes"] + m_scan["temp_bytes"]) / 1e9,
        "raw": full,
    }


def _dominant(t):
    return max(("compute", t["compute_s"]), ("memory", t["memory_s"]),
               ("collective", t["collective_s"]), key=lambda x: x[1])[0]


# ---------------------------------------------------------------------------
# variant definitions
# ---------------------------------------------------------------------------

def cell_qwen3_train():
    """qwen3-0.6b train_4k: memory-bound."""
    from repro.configs import SHAPES, get_config
    from repro.distributed.sharding import ShardingPolicy
    cfg = get_config("qwen3_0_6b")
    shape = SHAPES["train_4k"]
    return cfg, shape, [
        dict(name="baseline",
             hypothesis="naive attention materializes fp32 [S,S] scores "
                        "per head per layer; expect memory-dominated",
             predict="memory >> compute"),
        dict(name="chunked_attention",
             cfg_kw={"attn_impl": "chunked"},
             hypothesis="online-softmax over 1024-wide KV chunks removes "
                        "the [4096,4096] score materialization; per-device "
                        "score traffic drops ~Sk/chunk = 4x on the "
                        "attention part of HBM bytes",
             predict="memory_s down >=2x; flops slightly down "
                     "(no masked-lane waste); collective unchanged"),
        dict(name="chunked+dp_over_both_axes",
             cfg_kw={"attn_impl": "chunked"},
             pol=ShardingPolicy(tp_axis=None,
                                dp_axes=("data", "model"),
                                batch_axes=("data", "model")),
             hypothesis="0.6B params (1.2 GB bf16) fit replicated; "
                        "256-way pure-DP removes every per-layer TP "
                        "activation collective, leaving one 2.4GB/dev "
                        "gradient all-reduce",
             predict="collective_s down >5x; memory/compute about flat"),
        dict(name="kernel_attention(analytic)",
             cfg_kw={"attn_impl": "noscore"},
             pol=ShardingPolicy(tp_axis=None,
                                dp_axes=("data", "model"),
                                batch_axes=("data", "model")),
             analytic_attn_bytes=True,
             hypothesis="XLA's chunked attention still streams score "
                        "blocks through HBM (dot outputs are real "
                        "buffers); the Pallas flash kernel holds them in "
                        "VMEM, so attention HBM traffic collapses to "
                        "q/k/v/o (+bwd recompute).  Model it as the "
                        "score-free build + analytic qkvo traffic",
             predict="memory_s down 2-4x vs chunked; memory stops "
                     "dominating"),
    ]


def cell_granite_train():
    """granite-moe train_4k: collective-bound (MoE dispatch)."""
    from repro.configs import SHAPES, get_config
    cfg = get_config("granite_moe_1b_a400m")
    shape = SHAPES["train_4k"]
    return cfg, shape, [
        dict(name="baseline",
             hypothesis="MoE routing's slot-assignment cumsum over 8.4M "
                        "token-copies lowers to a QUADRATIC reduce-window "
                        "(measured 1.4e14 counted flops for the routing "
                        "alone) and the scatter dispatch through the "
                        "EP-sharded [E,C,d] buffer adds pod-wide "
                        "all-reduces",
             predict="compute- and collective-heavy, tiny MODEL/HLO"),
        dict(name="assoc_scan_routing",
             cfg_kw={"moe_impl": "scatter_fast"},
             hypothesis="log-depth associative_scan replaces the "
                        "quadratic cumsum: routing flops drop ~75,000x "
                        "(1.4e14 -> 1.9e9 measured in isolation); "
                        "dispatch collectives unchanged",
             predict="compute_s down >5x; collective_s roughly flat"),
        dict(name="dense_gshard_dispatch",
             cfg_kw={"moe_impl": "dense"},
             hypothesis="einsum dispatch with batch-grouped [B,S,E,C] "
                        "masks keeps routing local to the data shard; "
                        "no scatter/gather left for GSPMD to mis-shard",
             predict="collective_s down >=2x vs assoc_scan; dispatch "
                     "einsum flops up but stay non-dominant"),
        dict(name="dense+chunked_attention",
             cfg_kw={"moe_impl": "dense", "attn_impl": "chunked"},
             hypothesis="with dispatch fixed, memory dominates via "
                        "attention scores; chunked attention removes them "
                        "as in the qwen3 cell",
             predict="memory_s down ~2x vs previous variant"),
    ]


def cell_grok_decode():
    """grok-1-314b decode_32k: collective catastrophe + HBM violation."""
    from repro.configs import SHAPES, get_config
    from repro.distributed.sharding import ShardingPolicy
    cfg = get_config("grok_1_314b")
    shape = SHAPES["decode_32k"]
    return cfg, shape, [
        dict(name="baseline",
             hypothesis="param_count > 5e10 triggers FSDP; decode then "
                        "all-gathers ~2.4GB/dev of weights EVERY token; "
                        "also KV cache is only batch-sharded (16-way): "
                        "1.1TB/16 = 69GB/dev >> 16GB HBM — infeasible",
             predict="collective-dominated AND over HBM capacity"),
        dict(name="resident_2d_weights",
             pol=ShardingPolicy(two_d=True, fsdp=False, batch_axes=()),
             hypothesis="shard every large weight over all 256 chips "
                        "(('data','model') combined axis): 628GB bf16 -> "
                        "2.5GB/dev RESIDENT, no per-token gathers; decode "
                        "batch (128 tokens) replicated: activation "
                        "all-reduces are ~MB-scale; KV cache sequence-"
                        "sharded 256-way: 1.1TB -> 4.3GB/dev",
             predict="collective_s down >20x; hbm_per_dev under 16GB"),
        dict(name="resident_2d+int8_kv",
             cfg_kw={"kv_quant": True},
             pol=ShardingPolicy(two_d=True, fsdp=False, batch_axes=()),
             hypothesis="int8 KV with per-(pos,head) fp16 scales halves "
                        "both the cache footprint (4.3 -> 2.2 GB/dev) and "
                        "the attention's cache-read bytes; dequant fuses "
                        "into the score dot's operand load",
             predict="memory_s down ~1.5-2x; hbm_per_dev down ~2GB"),
    ]


def cell_grok_train():
    """BONUS cell: grok-1-314b train_4k — the worst absolute cell in the
    table (450 s collective term).  The granite fixes should transfer."""
    from repro.configs import SHAPES, get_config
    cfg = get_config("grok_1_314b")
    shape = SHAPES["train_4k"]
    return cfg, shape, [
        dict(name="baseline",
             hypothesis="314B params force FSDP (param all-gathers per "
                        "layer fwd+bwd) on top of the MoE scatter "
                        "dispatch and quadratic routing cumsum",
             predict="collective >> all; compute inflated by routing"),
        dict(name="assoc_scan+dense_dispatch",
             cfg_kw={"moe_impl": "dense"},
             hypothesis="granite's two MoE fixes transfer: log-depth "
                        "routing + batch-grouped einsum dispatch; FSDP "
                        "weight gathers remain (they are needed at 314B)",
             predict="collective down 2-5x (dispatch share), compute "
                     "drops to real expert flops"),
        dict(name="dense+chunked_attention",
             cfg_kw={"moe_impl": "dense", "attn_impl": "chunked"},
             hypothesis="removes the [4096,4096] score materialization "
                        "from the memory term (48 heads, 8 kv)",
             predict="memory_s down >=1.5x"),
    ]


CELLS = {
    "qwen3_train": cell_qwen3_train,
    "granite_train": cell_granite_train,
    "grok_decode": cell_grok_decode,
    "grok_train": cell_grok_train,
}


def run_cell(name: str, builder, memo: bool = True) -> dict:
    from repro.core.compile_cache import default_cache
    from repro.launch.mesh import make_production_mesh
    cfg0, shape, variants = builder()
    mesh = make_production_mesh()
    rows = []
    prev = None
    for v in variants:
        cfg = dataclasses.replace(cfg0, **v.get("cfg_kw", {}))
        print(f"[perf:{name}] {v['name']} ...", flush=True)
        hits0 = default_cache().stats.memo_hits
        t_meas0 = time.perf_counter()
        try:
            t = _measure_variant(cfg, shape, mesh, pol=v.get("pol"),
                                 remat=v.get("remat", True), memo=memo)
            t["measure_s"] = round(time.perf_counter() - t_meas0, 3)
            t["memo_hits"] = default_cache().stats.memo_hits - hits0
            if v.get("analytic_attn_bytes"):
                # add the flash kernel's own HBM/flop footprint on top of
                # the score-free build (q/k/v/o streamed once fwd + ~2x in
                # the bwd recompute; scores stay in VMEM)
                nd = mesh.size
                tloc = shape.tokens / nd
                hd, nh, nkv, L = cfg.hd, cfg.n_heads, cfg.n_kv_heads, \
                    cfg.n_layers
                attn_bytes = L * tloc * hd * (2 * nh + 2 * nkv) * 2 * 3
                attn_flops = (L * 3 * 0.5 * 2 * 2
                              * tloc * shape.seq_len * nh * hd)
                t["memory_s"] += attn_bytes / HW["hbm_bw"]
                t["compute_s"] += attn_flops / HW["peak_flops"]
                t["analytic_attn"] = {"bytes": attn_bytes,
                                      "flops": attn_flops}
            row = {"variant": v["name"], "hypothesis": v["hypothesis"],
                   "prediction": v["predict"], **t,
                   "dominant": _dominant(t)}
            if prev is not None:
                row["delta_vs_prev"] = {
                    k: round(prev[k] / t[k], 2) if t[k] else None
                    for k in ("compute_s", "memory_s", "collective_s")}
            prev = t
            print(f"  comp={t['compute_s']*1e3:.1f}ms "
                  f"mem={t['memory_s']*1e3:.1f}ms "
                  f"coll={t['collective_s']*1e3:.1f}ms "
                  f"hbm={t['hbm_per_dev_gb']:.1f}GB dom={row['dominant']}")
        except Exception as e:  # noqa: BLE001
            row = {"variant": v["name"], "error": repr(e)[:500]}
            print(f"  FAILED: {repr(e)[:200]}")
        rows.append(row)
    return {"cell": name, "arch": cfg0.name, "shape": shape.name,
            "variants": rows}


def _trajectory(results: dict) -> dict:
    """Flatten the hillclimb into the shared BENCH schema (one row per
    (cell, variant) with terms + memoization provenance)."""
    rows = []
    for cell in results.values():
        for v in cell.get("variants", []):
            if "error" in v:
                rows.append({"cell": cell["cell"], "variant": v["variant"],
                             "error": v["error"][:120]})
                continue
            rows.append({
                "cell": cell["cell"], "variant": v["variant"],
                "compute_s": v["compute_s"], "memory_s": v["memory_s"],
                "collective_s": v["collective_s"],
                "dominant": v["dominant"],
                "measure_s": v.get("measure_s"),
                "memo_hits": v.get("memo_hits", 0)})
    return {"benchmark": "perf_iter",
            "config": {"cells": sorted(results)}, "rows": rows}


def main(argv=None):
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all", choices=["all", *CELLS])
    ap.add_argument("--no-memo", action="store_true",
                    help="bypass the compile-cache memo (fresh measurement "
                         "of every variant)")
    args = ap.parse_args(argv)
    # single artifact: the root BENCH file carries both the flattened
    # trajectory rows and the raw per-cell state (which doubles as the
    # resumable sweep record the old out/perf_iter.json duplicated)
    prior = read_bench("perf_iter") or {}
    results = dict(prior.get("cells", {}))
    for name, builder in CELLS.items():
        if args.cell not in ("all", name):
            continue
        results[name] = run_cell(name, builder, memo=not args.no_memo)
        write_bench("perf_iter", {**_trajectory(results), "cells": results})
    write_bench("perf_iter", {**_trajectory(results), "cells": results})
    return results


if __name__ == "__main__":
    main()
