"""Fig. 8 reproduction + persistent-cache codegen benchmark.

The paper's claim: compiling each task *definition* once (and in parallel)
instead of once per *instance* accelerates RTL codegen 6.8x on a 32-thread
host; the fast edit-compile-measure loop is the third productivity pillar.
Three XLA-analogue measurements:

1. **Stage-graph compilation** (core/hier_compile.py): a dataflow graph of
   N instances stamped from K definitions (systolic-array shape, like the
   paper's gaussian with 564 instances of 15 tasks).  ``monolithic``
   lower+compiles every instance; ``hierarchical`` deduplicates by
   (definition, shape signature) and compiles the K unique ones through a
   thread pool.

2. **In-program form**: an L-layer transformer compiled as ``lax.scan``
   over stacked weights (body traced/optimized once — TAPA's
   compile-once) versus a Python-unrolled loop (XLA re-optimizes L inlined
   copies — the monolithic baseline).

3. **Cold / warm / incremental** (core/compile_cache.py): a 515-instance
   15-definition gaussian-style graph compiled three ways — *cold* (empty
   content-addressed store: 15 XLA compiles), *warm* (fresh process
   simulated by dropping the in-memory level and XLA's own caches; every
   definition loads from disk: 0 compiles), and *incremental* (one
   definition edited, previous CompileReport passed back in: 1 compile —
   the paper's QoR-tuning cycle).  Results + regression gates are
   persisted to ``BENCH_codegen_time.json`` at the repo root:
   warm must be >=5x faster than cold, the one-definition edit >=3x
   faster than a full hierarchical recompile.
"""

from __future__ import annotations

import argparse
import shutil
import sys
import tempfile
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.compile_cache import CompileCache
from repro.core.hier_compile import StageInstance, compile_stages

try:
    from benchmarks._bench import bench_path, write_bench
except ImportError:                     # script mode: python benchmarks/...
    from _bench import bench_path, write_bench

BENCH_JSON = bench_path("codegen_time")

WARM_BAR = 5.0          # warm start must beat cold by this factor
INCREMENTAL_BAR = 3.0   # one-def edit must beat full recompile by this


# --- 1. stage-graph dedup ----------------------------------------------------

def _make_defs():
    """Three stage definitions (feeder / PE / reducer shapes)."""
    def feeder(x):
        return jnp.tanh(x) * 1.5

    def pe(x):
        return jnp.tanh(x @ x.T) @ x

    def reducer(x):
        return jnp.cumsum(x, axis=0) / (1.0 + jnp.abs(x))

    return [feeder, pe, reducer]


def stage_graph_bench(n_instances: int = 24, dim: int = 256) -> dict:
    defs = _make_defs()
    x = jnp.ones((dim, dim), jnp.float32)

    def instances():
        return [StageInstance(fn=defs[i % len(defs)], args=(x,),
                              name=f"inst{i}")
                for i in range(n_instances)]

    out = {}
    for mode in ("monolithic", "hierarchical"):
        jax.clear_caches()
        # cache=False: this section isolates the *dedup* factor, so both
        # modes pay real compiles (the persistent store is section 3's job)
        rep = compile_stages(instances(), mode=mode, cache=False)
        out[mode] = {"wall_s": round(rep.wall_s, 3),
                     "n_instances": rep.n_instances,
                     "n_unique": rep.n_unique}
    out["speedup"] = round(out["monolithic"]["wall_s"] /
                           out["hierarchical"]["wall_s"], 2)
    out["dedup_factor"] = n_instances / len(defs)
    return out


# --- 2. scan vs unroll -------------------------------------------------------

def scan_vs_unroll_bench(n_layers: int = 12, d: int = 128,
                         batch: int = 4, seq: int = 64) -> dict:
    def layer(h, w):
        a = jnp.tanh(h @ w["w1"])
        return h + a @ w["w2"], None

    ws = {"w1": jnp.ones((n_layers, d, 4 * d)),
          "w2": jnp.ones((n_layers, 4 * d, d))}
    x = jnp.ones((batch, seq, d))

    def f_scan(ws, x):
        h, _ = jax.lax.scan(layer, x, ws)
        return h.sum()

    def f_unroll(ws, x):
        h = x
        for i in range(n_layers):
            h, _ = layer(h, jax.tree.map(lambda v: v[i], ws))
        return h.sum()

    out = {}
    for name, f in (("scan", f_scan), ("unroll", f_unroll)):
        jax.clear_caches()
        t0 = time.perf_counter()
        jax.jit(jax.grad(f)).lower(ws, x).compile()
        out[name] = {"compile_s": round(time.perf_counter() - t0, 3)}
    out["speedup"] = round(out["unroll"]["compile_s"] /
                           out["scan"]["compile_s"], 2)
    out["n_layers"] = n_layers
    return out


# --- 3. cold / warm / incremental through the persistent cache ---------------

def _gaussian_style_defs(n_defs: int, edit: int = -1):
    """``n_defs`` distinct stage definitions (distinct closure constants),
    re-created on every call — exactly what a tuning edit does to real
    stage closures.  ``edit`` bumps one definition's constant, simulating
    a one-task QoR edit (gaussian: tweak 1 of the 15 task definitions)."""
    def make(i: int, coef: float):
        def stage(x):
            y = jnp.tanh(x @ x.T) * coef
            return y + jnp.roll(x, (i % 3) + 1, axis=0) * (0.1 * (i + 1))
        return stage
    return [make(i, 0.5 + 0.1 * i + (1.0 if i == edit else 0.0))
            for i in range(n_defs)]


def _row(phase: str, rep) -> dict:
    return {"phase": phase, "wall_s": round(rep.wall_s, 4),
            "n_instances": rep.n_instances, "n_unique": rep.n_unique,
            "n_compiled": rep.n_compiled, "n_cache_hits": rep.n_cache_hits,
            "n_reused": rep.n_reused}


def cache_bench(n_instances: int = 515, n_defs: int = 15,
                dim: int = 96) -> dict:
    """The paper's QoR-tuning cycle, measured: cold build, warm restart,
    one-definition edit — on a 515-instance / 15-definition graph."""
    root = Path(tempfile.mkdtemp(prefix="repro-codegen-cache-"))
    try:
        cache = CompileCache(root=root)
        x = jnp.ones((dim, dim), jnp.float32)

        def instances(defs):
            return [StageInstance(fn=defs[i % len(defs)], args=(x,),
                                  name=f"inst{i}")
                    for i in range(n_instances)]

        jax.clear_caches()
        rep_cold = compile_stages(instances(_gaussian_style_defs(n_defs)),
                                  cache=cache)
        assert rep_cold.n_compiled == n_defs, rep_cold.sources

        # warm start: what a process restart costs — in-memory level and
        # XLA's own caches gone, closures re-created, disk store intact
        cache.clear_memory()
        jax.clear_caches()
        rep_warm = compile_stages(instances(_gaussian_style_defs(n_defs)),
                                  cache=cache)
        assert rep_warm.n_compiled == 0, rep_warm.sources

        # incremental: edit ONE definition, hand back the previous report —
        # only the dirty definition compiles (14/15 reused untouched)
        jax.clear_caches()
        rep_inc = compile_stages(
            instances(_gaussian_style_defs(n_defs, edit=0)),
            cache=CompileCache(root=root / "inc", disk=True),
            prev=rep_warm)
        assert rep_inc.n_compiled == 1 and rep_inc.n_reused == n_defs - 1, \
            rep_inc.sources

        rows = [_row("cold", rep_cold), _row("warm", rep_warm),
                _row("incremental", rep_inc)]
        warm_speedup = round(rep_cold.wall_s / max(rep_warm.wall_s, 1e-9), 2)
        inc_speedup = round(rep_cold.wall_s / max(rep_inc.wall_s, 1e-9), 2)
        gates = {
            "warm_speedup": warm_speedup, "warm_bar": WARM_BAR,
            "incremental_speedup": inc_speedup,
            "incremental_bar": INCREMENTAL_BAR,
            "pass": warm_speedup >= WARM_BAR
                    and inc_speedup >= INCREMENTAL_BAR,
        }
        return {"config": {"n_instances": n_instances, "n_defs": n_defs,
                           "dim": dim},
                "rows": rows, "gates": gates}
    finally:
        shutil.rmtree(root, ignore_errors=True)


# --- driver ------------------------------------------------------------------

def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: shrink the Fig.8 sections (the cache "
                         "section always runs at full instance count — the "
                         "515 instances cost hashing, not compiles)")
    args = ap.parse_args(argv)

    if args.quick:
        res = {"stage_graph": stage_graph_bench(n_instances=12, dim=128),
               "scan_vs_unroll": scan_vs_unroll_bench(n_layers=6)}
    else:
        res = {"stage_graph": stage_graph_bench(),
               "scan_vs_unroll": scan_vs_unroll_bench()}
    cb = cache_bench()
    res["cache"] = cb
    res["codegen_regression"] = not cb["gates"]["pass"]

    # one root record (shared schema: benchmark/config/rows/gates); the
    # Fig.8 sections ride along instead of duplicating under out/
    write_bench("codegen_time", {
        "benchmark": "codegen_time", **cb,
        "stage_graph": res["stage_graph"],
        "scan_vs_unroll": res["scan_vs_unroll"],
    })

    sg, su = res["stage_graph"], res["scan_vs_unroll"]
    print(f"stage graph : monolithic {sg['monolithic']['wall_s']}s "
          f"({sg['monolithic']['n_instances']} compiles) vs hierarchical "
          f"{sg['hierarchical']['wall_s']}s ({sg['hierarchical']['n_unique']}"
          f" compiles) -> {sg['speedup']}x")
    print(f"scan/unroll : unroll {su['unroll']['compile_s']}s vs scan "
          f"{su['scan']['compile_s']}s ({su['n_layers']} layers) -> "
          f"{su['speedup']}x")
    for r in cb["rows"]:
        print(f"cache {r['phase']:<11}: {r['wall_s']}s "
              f"(compiled {r['n_compiled']}, hits {r['n_cache_hits']}, "
              f"reused {r['n_reused']} of {r['n_unique']} defs, "
              f"{r['n_instances']} instances)")
    g = cb["gates"]
    print(f"gates       : warm {g['warm_speedup']}x (bar {g['warm_bar']}x) "
          f"| incremental {g['incremental_speedup']}x "
          f"(bar {g['incremental_bar']}x) -> "
          f"{'PASS' if g['pass'] else 'FAIL'}")
    print(f"wrote {BENCH_JSON}")
    print("paper claim : 6.8x codegen (32 hyper-threads; dedup x "
          "parallel-HLS)")
    if res["codegen_regression"]:
        print("CODEGEN REGRESSION: cache speedups under the bar",
              file=sys.stderr)
    return res


if __name__ == "__main__":
    sys.exit(1 if main().get("codegen_regression") else 0)
