"""Fig. 8 reproduction: hierarchical vs monolithic code generation.

The paper's claim: compiling each task *definition* once (and in parallel)
instead of once per *instance* accelerates RTL codegen 6.8x on a 32-thread
host.  The XLA analogue measured here, in two forms:

1. **Stage-graph compilation** (core/hier_compile.py): a dataflow graph of
   N instances stamped from K definitions (systolic-array shape, like the
   paper's gaussian with 564 instances of 15 tasks).  ``monolithic``
   lower+compiles every instance; ``hierarchical`` deduplicates by
   (definition, shape signature) and compiles the K unique ones through a
   thread pool.  Expected speedup ~ N/K x pool-parallelism; this container
   has 1 core, so the measured number isolates the dedup factor.

2. **In-program form**: an L-layer transformer compiled as ``lax.scan``
   over stacked weights (body traced/optimized once — TAPA's
   compile-once) versus a Python-unrolled loop (XLA re-optimizes L inlined
   copies — the monolithic baseline).
"""

from __future__ import annotations

import json
import time
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core.hier_compile import StageInstance, compile_stages

OUT = Path(__file__).parent / "out"


# --- 1. stage-graph dedup ----------------------------------------------------

def _make_defs():
    """Three stage definitions (feeder / PE / reducer shapes)."""
    def feeder(x):
        return jnp.tanh(x) * 1.5

    def pe(x):
        return jnp.tanh(x @ x.T) @ x

    def reducer(x):
        return jnp.cumsum(x, axis=0) / (1.0 + jnp.abs(x))

    return [feeder, pe, reducer]


def stage_graph_bench(n_instances: int = 24, dim: int = 256) -> dict:
    defs = _make_defs()
    x = jnp.ones((dim, dim), jnp.float32)

    def instances():
        return [StageInstance(fn=defs[i % len(defs)], args=(x,),
                              name=f"inst{i}")
                for i in range(n_instances)]

    out = {}
    for mode in ("monolithic", "hierarchical"):
        jax.clear_caches()
        rep = compile_stages(instances(), mode=mode)
        out[mode] = {"wall_s": round(rep.wall_s, 3),
                     "n_instances": rep.n_instances,
                     "n_unique": rep.n_unique}
    out["speedup"] = round(out["monolithic"]["wall_s"] /
                           out["hierarchical"]["wall_s"], 2)
    out["dedup_factor"] = n_instances / len(defs)
    return out


# --- 2. scan vs unroll -------------------------------------------------------

def scan_vs_unroll_bench(n_layers: int = 12, d: int = 128,
                         batch: int = 4, seq: int = 64) -> dict:
    def layer(h, w):
        a = jnp.tanh(h @ w["w1"])
        return h + a @ w["w2"], None

    ws = {"w1": jnp.ones((n_layers, d, 4 * d)),
          "w2": jnp.ones((n_layers, 4 * d, d))}
    x = jnp.ones((batch, seq, d))

    def f_scan(ws, x):
        h, _ = jax.lax.scan(layer, x, ws)
        return h.sum()

    def f_unroll(ws, x):
        h = x
        for i in range(n_layers):
            h, _ = layer(h, jax.tree.map(lambda v: v[i], ws))
        return h.sum()

    out = {}
    for name, f in (("scan", f_scan), ("unroll", f_unroll)):
        jax.clear_caches()
        t0 = time.perf_counter()
        jax.jit(jax.grad(f)).lower(ws, x).compile()
        out[name] = {"compile_s": round(time.perf_counter() - t0, 3)}
    out["speedup"] = round(out["unroll"]["compile_s"] /
                           out["scan"]["compile_s"], 2)
    out["n_layers"] = n_layers
    return out


def main() -> dict:
    res = {"stage_graph": stage_graph_bench(),
           "scan_vs_unroll": scan_vs_unroll_bench()}
    OUT.mkdir(exist_ok=True)
    (OUT / "codegen_time.json").write_text(json.dumps(res, indent=1))
    sg, su = res["stage_graph"], res["scan_vs_unroll"]
    print(f"stage graph : monolithic {sg['monolithic']['wall_s']}s "
          f"({sg['monolithic']['n_instances']} compiles) vs hierarchical "
          f"{sg['hierarchical']['wall_s']}s ({sg['hierarchical']['n_unique']}"
          f" compiles) -> {sg['speedup']}x")
    print(f"scan/unroll : unroll {su['unroll']['compile_s']}s vs scan "
          f"{su['scan']['compile_s']}s ({su['n_layers']} layers) -> "
          f"{su['speedup']}x")
    print("paper claim : 6.8x (32 hyper-threads; dedup x parallel-HLS)")
    return res


if __name__ == "__main__":
    main()
