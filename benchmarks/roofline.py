"""S:Roofline — three-term roofline per (arch x shape) on the 16x16 pod.

    compute_s    = HLO_FLOPs_per_device / peak_FLOP/s        (197 TF bf16)
    memory_s     = HLO_bytes_per_device / HBM_bw             (819 GB/s)
    collective_s = collective_bytes_per_device / link_bw     (~50 GB/s ICI)

cost_analysis() of the SPMD-compiled module is per-device (verified: flops
halve when the dp axis doubles), so no chip division is applied.

**Loop-body correction.**  XLA's cost analysis counts a while-loop body
ONCE regardless of trip count, and the production steps scan over layers
(the compile-once feature), so raw numbers undercount by ~n_layers.  We
recover the true per-step cost with a linear fit: lower the same step with
the layer stack *unrolled* at two shallow depths L1 < L2 —

    m(L) = fixed + L * per_layer       (dense/moe/ssm/vlm/audio)
    m(L, A) = fixed + L*mamba + A*attn (hybrid: A = shared-attn hits)

solve, then extrapolate to the full depth.  Collective bytes from the HLO
text get the same treatment.  The fit residual is checked by predicting
the scan-build measurement (fixed + per_layer must reproduce m_scan) and
reported per cell.

MODEL_FLOPS is the analytic useful compute: 6*N_active*D (train),
2*N_active*D (prefill), 2*N_active*B (decode, per emitted token); the
MODEL/HLO ratio exposes remat and dispatch overheads (attention's
quadratic term is excluded from MODEL_FLOPS by convention, so long-context
cells legitimately show ratios < 1).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path

OUT = Path(__file__).parent / "out"

HW = {
    "peak_flops": 197e12,        # bf16 per chip (TPU v5e)
    "hbm_bw": 819e9,             # B/s per chip
    "ici_bw": 50e9,              # B/s per link
}


def _measure(cfg, shape, mesh, scan_layers: bool) -> dict:
    """Lower+compile one step variant; return per-device flops/bytes/coll."""
    import jax
    from repro.launch.dryrun import collective_bytes
    from repro.launch.steps import input_specs

    spec = input_specs(cfg, shape, mesh, scan_layers=scan_layers)
    with mesh:
        compiled = jax.jit(
            spec["fn"], in_shardings=spec["in_shardings"],
            out_shardings=spec["out_shardings"],
            donate_argnums=spec["donate_argnums"]).lower(
                *spec["args"]).compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total_bytes"])}


def _variant_cfg(cfg, n_layers: int, period=None):
    kw = {"n_layers": n_layers}
    if cfg.encdec is not None:
        kw["encdec"] = dataclasses.replace(cfg.encdec,
                                           n_encoder_layers=n_layers)
    if cfg.hybrid is not None and period is not None:
        kw["hybrid"] = dataclasses.replace(cfg.hybrid, attn_period=period)
    return dataclasses.replace(cfg, **kw)


def fit_cell(cfg, shape, mesh) -> dict:
    """Reconstruct the full-depth per-device cost of the production (scan)
    build, handling XLA's two loop-accounting regimes *per metric*:

    * some builds count the while-loop body once regardless of trips
      (observed for train steps) — recover via a linear fit over shallow
      UNROLLED variants: m(L) = fixed + L*per_layer;
    * others scale with trip count already (observed for decode steps,
      where XLA unrolls/accounts the cache-update loop) — the full scan
      build's raw number is already correct.

    The regime test is empirical: measure the scan build at depths 2 and 4;
    a metric that grows >=1.6x is trip-accounted.
    """
    keys = ("flops", "bytes", "coll")
    L = cfg.n_layers
    p_small = 2 if cfg.hybrid is not None else None
    s2 = _measure(_variant_cfg(cfg, 2, period=p_small), shape, mesh, True)
    s4 = _measure(_variant_cfg(cfg, 4, period=p_small), shape, mesh, True)
    m_scan = _measure(cfg, shape, mesh, True)
    scales = {k: s4[k] > 1.6 * max(s2[k], 1.0) for k in keys}
    detail = {"s2": s2, "s4": s4, "m_scan": m_scan, "scales": scales}

    full = {}
    need_unroll = [k for k in keys if not scales[k]]
    if need_unroll:
        if cfg.hybrid is not None:
            # m(L, A) = fixed + L*mamba + A*attn
            m42 = _measure(_variant_cfg(cfg, 4, period=2), shape, mesh,
                           False)
            m41 = _measure(_variant_cfg(cfg, 4, period=4), shape, mesh,
                           False)
            m21 = _measure(_variant_cfg(cfg, 2, period=2), shape, mesh,
                           False)
            A_full = sum(1 for i in range(L)
                         if (i % cfg.hybrid.attn_period)
                         == cfg.hybrid.attn_period - 1)
            detail.update(m42=m42, m41=m41, m21=m21, A_full=A_full)
            for k in need_unroll:
                attn = m42[k] - m41[k]
                mamba = (m41[k] - m21[k]) / 2.0
                fixed = m21[k] - 2 * mamba - attn
                full[k] = max(fixed + L * mamba + A_full * attn, 0.0)
        else:
            if cfg.encdec is not None:
                assert cfg.encdec.n_encoder_layers == cfg.n_layers, \
                    "fit assumes L_enc == L_dec (true for whisper-small)"
            m2 = _measure(_variant_cfg(cfg, 2), shape, mesh, False)
            m4 = _measure(_variant_cfg(cfg, 4), shape, mesh, False)
            detail.update(m2=m2, m4=m4)
            for k in need_unroll:
                per_layer = (m4[k] - m2[k]) / 2.0
                fixed = m2[k] - 2 * per_layer
                full[k] = max(fixed + L * per_layer, 0.0)
    for k in keys:
        if scales[k]:
            full[k] = m_scan[k]
    full["scan_flops_raw"] = m_scan["flops"]
    full["scan_coll_raw"] = m_scan["coll"]
    return {"full": full, "detail": detail}


def model_flops_per_device(cfg, shape, n_devices: int) -> float:
    n = cfg.active_param_count()
    if shape.kind == "train":
        f = 6.0 * n * shape.tokens
    elif shape.kind == "prefill":
        f = 2.0 * n * shape.tokens
    else:                              # decode: one token per sequence
        f = 2.0 * n * shape.global_batch
    return f / n_devices


def roofline_row(arch: str, shape_name: str, fitted: dict, cfg,
                 shape, n_devices: int) -> dict:
    full = fitted["full"]
    comp = full["flops"] / HW["peak_flops"]
    mem = full["bytes"] / HW["hbm_bw"]
    coll = full["coll"] / HW["ici_bw"]
    dom = max(("compute", comp), ("memory", mem), ("collective", coll),
              key=lambda t: t[1])
    mf = model_flops_per_device(cfg, shape, n_devices)
    bound = max(comp, mem, coll)
    # roofline fraction: useful-FLOP time over the bound term (how close
    # the step is to the best achievable given its own dominant resource)
    frac = (mf / HW["peak_flops"]) / bound if bound > 0 else 0.0
    return {
        "arch": arch, "shape": shape_name,
        "compute_s": comp, "memory_s": mem, "collective_s": coll,
        "dominant": dom[0],
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": full["flops"],
        "model_over_hlo": mf / full["flops"] if full["flops"] else 0.0,
        "roofline_fraction": frac,
    }


NOTES = {
    "compute": "raise arithmetic efficiency: cut remat recompute, fuse "
               "dispatch, larger MXU tiles",
    "memory": "cut HBM traffic: fuse elementwise chains, bf16 "
              "activations, avoid re-layout copies",
    "collective": "cut link bytes: reshard to keep weights resident, "
                  "overlap or eliminate gathers, EP all-to-all",
}


def main(argv=None) -> dict:
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
    from repro.launch.mesh import make_production_mesh

    OUT.mkdir(exist_ok=True)
    path = OUT / "roofline.json"
    cache = json.loads(path.read_text()) if path.exists() else {}

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    mesh = make_production_mesh()
    nd = mesh.size

    for arch in archs:
        cfg = get_config(arch)
        for sn in shapes:
            shape = SHAPES[sn]
            ok, why = shape_applicable(cfg, shape)
            key = f"{cfg.name}|{sn}"
            if not ok:
                cache[key] = {"skipped": why}
                continue
            if key in cache and "row" in cache[key] and not args.force:
                continue
            print(f"[roofline] fitting {key} ...", flush=True)
            try:
                fitted = fit_cell(cfg, shape, mesh)
                row = roofline_row(cfg.name, sn, fitted, cfg, shape, nd)
                cache[key] = {"row": row, "fit": fitted["detail"],
                              "full": fitted["full"]}
                r = row
                print(f"  comp={r['compute_s']*1e3:.2f}ms "
                      f"mem={r['memory_s']*1e3:.2f}ms "
                      f"coll={r['collective_s']*1e3:.2f}ms "
                      f"dom={r['dominant']} frac={r['roofline_fraction']:.3f}")
            except Exception as e:  # noqa: BLE001
                print(f"  FAILED: {e!r}")
                cache[key] = {"error": repr(e)}
            path.write_text(json.dumps(cache, indent=1))
    path.write_text(json.dumps(cache, indent=1))

    # markdown table
    lines = ["| arch | shape | compute | memory | collective | dominant | "
             "MODEL/HLO | roofline frac | next lever |",
             "|---|---|---|---|---|---|---|---|---|"]
    for key, v in sorted(cache.items()):
        if "row" not in v:
            continue
        r = v["row"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']*1e3:.2f} ms | "
            f"{r['memory_s']*1e3:.2f} ms | {r['collective_s']*1e3:.2f} ms | "
            f"{r['dominant']} | {r['model_over_hlo']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {NOTES[r['dominant']]} |")
    (OUT / "roofline.md").write_text("\n".join(lines) + "\n")
    print("\n".join(lines))
    return cache


if __name__ == "__main__":
    main()
